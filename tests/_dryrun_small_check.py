"""Subprocess body: the dry-run machinery (build_cell: specs, shardings,
lower, compile, roofline extraction) on a small 2x4 mesh with smoke
configs — CI-speed proof that the production-path plumbing works for all
step kinds and model families."""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import dataclasses  # noqa: E402

import jax  # noqa: E402

from repro.utils.jax_compat import make_mesh  # noqa: E402

from repro import configs  # noqa: E402
from repro.launch import specs as specs_lib  # noqa: E402
from repro.utils import hlo as hlo_lib  # noqa: E402

CELLS = [
    ("smollm-135m", "train_4k"),
    ("mixtral-8x22b", "train_4k"),      # MoE path
    ("mamba2-780m", "decode_32k"),      # SSM state cache
    ("gemma2-2b", "long_500k"),         # ring KV cache + softcap
    ("whisper-small", "decode_32k"),    # enc-dec memory_kv
    ("llava-next-34b", "prefill_32k"),  # patch prefix
]


def _shrink_shapes():
    # shrink the global shape table so smoke cells compile in seconds
    specs_lib.SHAPES.clear()
    specs_lib.SHAPES.update({
        "train_4k": (64, 8, "train"),
        "prefill_32k": (128, 8, "prefill"),
        "decode_32k": (128, 8, "decode"),
        "long_500k": (256, 8, "decode"),
    })
    import repro.configs.common as common
    common.SHAPES = specs_lib.SHAPES


def main():
    assert jax.device_count() == 8
    _shrink_shapes()
    mesh = make_mesh((2, 4), ("data", "model"))

    # monkeypatch the registry to smoke configs
    real_get = configs.get_config
    configs.get_config = lambda a: configs.reduced(real_get(a))
    specs_lib._param_struct.cache_clear()

    for arch, shape in CELLS:
        fn, args, in_sh, donate, meta = specs_lib.build_cell(
            arch, shape, mesh)
        with mesh:
            lowered = jax.jit(fn, in_shardings=in_sh,
                              donate_argnums=donate).lower(*args)
            compiled = lowered.compile()
        mem = compiled.memory_analysis()
        assert mem is not None
        roof = hlo_lib.roofline_from_compiled(compiled, mesh.size)
        assert roof.flops > 0
        print(f"OK {arch} x {shape}: flops={roof.flops:.2e} "
              f"coll={roof.coll_bytes:.2e} bottleneck={roof.bottleneck}")
    print("DRYRUN_SMALL_OK")


if __name__ == "__main__":
    main()
