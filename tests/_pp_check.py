"""Subprocess body for the pipeline-parallel test (4 placeholder devices,
4 stages): GPipe microbatched apply must equal sequential layer apply."""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.runtime.pipeline_parallel import pipeline_apply, stack_stages  # noqa: E402
from repro.utils.jax_compat import make_mesh  # noqa: E402


def main():
    assert jax.device_count() == 4
    mesh = make_mesh((4,), ("pod",))
    n_layers, d, b = 8, 32, 16
    key = jax.random.PRNGKey(0)
    ws = jax.random.normal(key, (n_layers, d, d)) * 0.3
    x = jax.random.normal(jax.random.fold_in(key, 1), (b, d))

    def layer(w, h):
        return jnp.tanh(h @ w)

    # sequential reference
    ref = x
    for i in range(n_layers):
        ref = layer(ws[i], ref)

    def stage_fn(stage_params, h):
        def body(carry, w):
            return layer(w, carry), None
        out, _ = jax.lax.scan(body, h, stage_params["w"])
        return out

    stages = stack_stages({"w": ws}, 4)["w"]  # (4, 2, d, d)
    got = pipeline_apply(stage_fn, {"w": stages}, x, mesh=mesh,
                         axis="pod", n_microbatches=4)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)
    print("PP_OK: pipelined == sequential over", n_layers, "layers")


if __name__ == "__main__":
    main()
