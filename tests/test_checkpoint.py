"""Checkpoint manager: atomicity, keep-k GC, restore exactness, elastic
restore hook, corruption resistance."""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager


def _tree(seed):
    k = jax.random.PRNGKey(seed)
    return {"a": jax.random.normal(k, (8, 16)),
            "nested": {"b": jnp.arange(10, dtype=jnp.int32),
                       "c": jnp.float32(3.5)},
            "step": jnp.int32(7)}


def test_save_restore_bit_exact(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    tree = _tree(0)
    mgr.save(42, tree)
    like = jax.tree.map(jnp.zeros_like, tree)
    out = mgr.restore(like)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_keep_k_gc(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        mgr.save(s, _tree(s))
    assert mgr.all_steps() == [3, 4]


def test_latest_and_explicit_step(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=5)
    mgr.save(1, _tree(1))
    mgr.save(2, _tree(2))
    like = jax.tree.map(jnp.zeros_like, _tree(0))
    r1 = mgr.restore(like, step=1)
    r2 = mgr.restore(like)
    assert mgr.latest_step() == 2
    assert not np.array_equal(np.asarray(r1["a"]), np.asarray(r2["a"]))


def test_partial_write_is_invisible(tmp_path):
    """A .tmp dir (simulated crash mid-save) must not be listed/restored."""
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(5, _tree(5))
    crash = os.path.join(str(tmp_path), "step_00000009.tmp")
    os.makedirs(crash)
    with open(os.path.join(crash, "meta.json"), "w") as f:
        json.dump({"step": 9, "leaves": []}, f)
    assert mgr.all_steps() == [5]
    assert mgr.latest_step() == 5


def test_shape_mismatch_raises(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, {"a": jnp.zeros((4,))})
    with pytest.raises(ValueError):
        mgr.restore({"a": jnp.zeros((5,))})


def test_elastic_restore_put_hook(tmp_path):
    """put() can re-device_put with a new sharding (elastic rescale)."""
    mgr = CheckpointManager(str(tmp_path))
    tree = _tree(3)
    mgr.save(1, tree)
    names_seen = []

    def put(name, arr):
        names_seen.append(name)
        return jax.device_put(arr)

    out = mgr.restore(jax.tree.map(jnp.zeros_like, tree), put=put)
    assert len(names_seen) == len(jax.tree.leaves(tree))
    np.testing.assert_array_equal(np.asarray(out["a"]),
                                  np.asarray(tree["a"]))


def test_async_save(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    tree = _tree(4)
    mgr.save(1, tree, blocking=False)
    mgr.wait()
    assert mgr.all_steps() == [1]
