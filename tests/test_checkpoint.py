"""Checkpoint manager: atomicity, keep-k GC, restore exactness, elastic
restore hook, corruption resistance."""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager


def _tree(seed):
    k = jax.random.PRNGKey(seed)
    return {"a": jax.random.normal(k, (8, 16)),
            "nested": {"b": jnp.arange(10, dtype=jnp.int32),
                       "c": jnp.float32(3.5)},
            "step": jnp.int32(7)}


def test_save_restore_bit_exact(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    tree = _tree(0)
    mgr.save(42, tree)
    like = jax.tree.map(jnp.zeros_like, tree)
    out = mgr.restore(like)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_keep_k_gc(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        mgr.save(s, _tree(s))
    assert mgr.all_steps() == [3, 4]


def test_latest_and_explicit_step(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=5)
    mgr.save(1, _tree(1))
    mgr.save(2, _tree(2))
    like = jax.tree.map(jnp.zeros_like, _tree(0))
    r1 = mgr.restore(like, step=1)
    r2 = mgr.restore(like)
    assert mgr.latest_step() == 2
    assert not np.array_equal(np.asarray(r1["a"]), np.asarray(r2["a"]))


def test_partial_write_is_invisible(tmp_path):
    """A .tmp dir (simulated crash mid-save) must not be listed/restored."""
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(5, _tree(5))
    crash = os.path.join(str(tmp_path), "step_00000009.tmp")
    os.makedirs(crash)
    with open(os.path.join(crash, "meta.json"), "w") as f:
        json.dump({"step": 9, "leaves": []}, f)
    assert mgr.all_steps() == [5]
    assert mgr.latest_step() == 5


def test_shape_mismatch_raises(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, {"a": jnp.zeros((4,))})
    with pytest.raises(ValueError):
        mgr.restore({"a": jnp.zeros((5,))})


def test_elastic_restore_put_hook(tmp_path):
    """put() can re-device_put with a new sharding (elastic rescale)."""
    mgr = CheckpointManager(str(tmp_path))
    tree = _tree(3)
    mgr.save(1, tree)
    names_seen = []

    def put(name, arr):
        names_seen.append(name)
        return jax.device_put(arr)

    out = mgr.restore(jax.tree.map(jnp.zeros_like, tree), put=put)
    assert len(names_seen) == len(jax.tree.leaves(tree))
    np.testing.assert_array_equal(np.asarray(out["a"]),
                                  np.asarray(tree["a"]))


def test_async_save(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    tree = _tree(4)
    mgr.save(1, tree, blocking=False)
    mgr.wait()
    assert mgr.all_steps() == [1]


# ------------------------------------------------- checksums / corruption
def test_meta_records_per_leaf_checksum(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    tree = _tree(1)
    path = mgr.save(7, tree)
    with open(os.path.join(path, "meta.json")) as f:
        meta = json.load(f)
    assert len(meta["leaves"]) == len(jax.tree.leaves(tree))
    assert all(isinstance(d["crc32"], int) for d in meta["leaves"])


def test_checksum_detects_silent_bit_flip(tmp_path):
    from repro.checkpoint.manager import CheckpointCorruptError
    from repro.runtime.fault import damage_checkpoint
    mgr = CheckpointManager(str(tmp_path))
    tree = _tree(2)
    path = mgr.save(3, tree)
    assert damage_checkpoint(path, mode="corrupt") >= 1
    like = jax.tree.map(jnp.zeros_like, tree)
    with pytest.raises(CheckpointCorruptError):
        mgr.restore(like)
    # the flip keeps the .npy container valid: only the checksum sees
    # it, and verify=False (the escape hatch) loads the damaged bytes
    mgr.restore(like, verify=False)


def test_truncated_leaf_raises_corrupt_error(tmp_path):
    from repro.checkpoint.manager import CheckpointCorruptError
    from repro.runtime.fault import damage_checkpoint
    mgr = CheckpointManager(str(tmp_path))
    tree = _tree(2)
    path = mgr.save(3, tree)
    assert damage_checkpoint(path, mode="truncate") >= 1
    with pytest.raises(CheckpointCorruptError):
        mgr.restore(jax.tree.map(jnp.zeros_like, tree))


def test_fallback_walks_to_previous_intact_step(tmp_path):
    from repro.runtime.fault import damage_checkpoint
    mgr = CheckpointManager(str(tmp_path), keep=4)
    t1, t2 = _tree(1), _tree(2)
    mgr.save(1, t1)
    path2 = mgr.save(2, t2)
    damage_checkpoint(path2, mode="corrupt")
    like = jax.tree.map(jnp.zeros_like, t1)
    step, out = mgr.restore_with_fallback(like)
    assert step == 1
    np.testing.assert_array_equal(np.asarray(t1["a"]),
                                  np.asarray(out["a"]))


# ----------------------------------------------------- sharded checkpoints
def test_save_sharded_round_trip_reassembles(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    tree = {"state": jnp.arange(8 * 5 * 5,
                                dtype=jnp.int32).reshape(8, 5, 5),
            "step": jnp.int32(3)}
    path = mgr.save_sharded(4, tree, n_shards=8, axis=0)
    with open(os.path.join(path, "meta.json")) as f:
        meta = json.load(f)
    # one chunk file + one crc32 per shard for the array leaf; the
    # scalar is stored unsplit and stays out of the shard map
    assert meta["sharded"] == {"state": {"n_shards": 8, "axis": 0}}
    names = [d["name"] for d in meta["leaves"]]
    assert sum(n.startswith("state@s") for n in names) == 8
    assert "step" in names
    out = mgr.restore(jax.tree.map(jnp.zeros_like, tree))
    np.testing.assert_array_equal(np.asarray(out["state"]),
                                  np.asarray(tree["state"]))
    assert int(out["step"]) == 3


def test_sharded_restore_is_mesh_independent(tmp_path):
    """n_shards is a storage detail: the same like-tree restores no
    matter how many ways the saver split (uneven splits included) —
    the elastic 8->4 reshard depends on exactly this."""
    tree = {"x": jnp.arange(10 * 4, dtype=jnp.float32).reshape(10, 4)}
    like = jax.tree.map(jnp.zeros_like, tree)
    for n in (1, 3, 8):
        mgr = CheckpointManager(str(tmp_path / f"n{n}"))
        mgr.save_sharded(1, tree, n_shards=n)
        out = mgr.restore(like)
        np.testing.assert_array_equal(np.asarray(out["x"]),
                                      np.asarray(tree["x"]))


def test_damaged_shard_chunk_is_localized_and_falls_back(tmp_path):
    """Flipping a byte in ONE shard chunk fails that chunk's crc32 (the
    whole step is then rejected) and restore_with_fallback walks to the
    previous intact step."""
    from repro.checkpoint.manager import CheckpointCorruptError
    mgr = CheckpointManager(str(tmp_path), keep=4)
    t1 = {"x": jnp.arange(16.0).reshape(8, 2)}
    t2 = {"x": jnp.arange(16.0).reshape(8, 2) + 100.0}
    like = jax.tree.map(jnp.zeros_like, t1)
    mgr.save_sharded(1, t1, n_shards=4)
    path2 = mgr.save_sharded(2, t2, n_shards=4)
    with open(os.path.join(path2, "meta.json")) as f:
        meta = json.load(f)
    fn = next(d["file"] for d in meta["leaves"]
              if d["name"] == "x@s001")
    with open(os.path.join(path2, fn), "r+b") as f:
        f.seek(-1, os.SEEK_END)
        last = f.read(1)
        f.seek(-1, os.SEEK_END)
        f.write(bytes([last[0] ^ 0xFF]))
    with pytest.raises(CheckpointCorruptError):
        mgr.restore(like)
    step, out = mgr.restore_with_fallback(like)
    assert step == 1
    np.testing.assert_array_equal(np.asarray(out["x"]),
                                  np.asarray(t1["x"]))


def test_save_sharded_async(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    tree = {"x": jnp.arange(12.0).reshape(6, 2)}
    mgr.save_sharded(1, tree, n_shards=3, blocking=False)
    mgr.wait()
    out = mgr.restore(jax.tree.map(jnp.zeros_like, tree))
    np.testing.assert_array_equal(np.asarray(out["x"]),
                                  np.asarray(tree["x"]))


def test_save_sharded_validates_n_shards(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    with pytest.raises(ValueError):
        mgr.save_sharded(1, {"x": jnp.zeros((4,))}, n_shards=0)


def test_fallback_exhausted_raises(tmp_path):
    from repro.checkpoint.manager import CheckpointCorruptError
    from repro.runtime.fault import damage_checkpoint
    mgr = CheckpointManager(str(tmp_path), keep=4)
    t = _tree(1)
    like = jax.tree.map(jnp.zeros_like, t)
    with pytest.raises(FileNotFoundError):
        mgr.restore_with_fallback(like)  # nothing saved yet
    for s in (1, 2):
        damage_checkpoint(mgr.save(s, t), mode="truncate")
    with pytest.raises(CheckpointCorruptError):
        mgr.restore_with_fallback(like)  # every step damaged
