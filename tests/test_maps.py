"""Unit + property tests for the lambda/nu space maps (paper Sections 3.3-3.4).

The binding spec is: nu is the exact inverse of lambda on the fractal, the
compact domain is a bijection onto the fractal cells, and the matmul (MXU)
encodings agree exactly with the integer paths.
"""
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # optional dev dep (requirements-dev.txt)
from hypothesis import given, settings, strategies as st

from repro.core import fractals, maps

ALL_FRACTALS = list(fractals.REGISTRY.values())
SMALL_LEVELS = [0, 1, 2, 3, 4]


def _all_compact_coords(frac, r):
    rows, cols = frac.compact_dims(r)
    cy, cx = np.meshgrid(np.arange(rows), np.arange(cols), indexing="ij")
    return cx.reshape(-1).astype(np.int32), cy.reshape(-1).astype(np.int32)


# ----------------------------------------------------------------- geometry
@pytest.mark.parametrize("frac", ALL_FRACTALS, ids=lambda f: f.name)
@pytest.mark.parametrize("r", SMALL_LEVELS)
def test_compact_dims_hold_volume(frac, r):
    rows, cols = frac.compact_dims(r)
    assert rows * cols == frac.volume(r)
    assert rows == frac.k ** (r // 2)
    assert cols == frac.k ** ((r + 1) // 2)


@pytest.mark.parametrize("frac", ALL_FRACTALS, ids=lambda f: f.name)
@pytest.mark.parametrize("r", SMALL_LEVELS)
def test_mask_cell_count_is_volume(frac, r):
    assert int(frac.mask(r).sum()) == frac.volume(r)


def test_sierpinski_hnu_matches_paper_hash():
    """Paper Eq. 22: H_nu[theta] == theta_x + theta_y for the Sierpinski."""
    f = fractals.SIERPINSKI
    for ty in range(2):
        for tx in range(2):
            code = f.h_nu[ty, tx]
            if code >= 0:
                assert code == tx + ty
    assert f.h_nu[0, 1] == -1  # the single hole


# --------------------------------------------------- lambda is a bijection
@pytest.mark.parametrize("frac", ALL_FRACTALS, ids=lambda f: f.name)
@pytest.mark.parametrize("r", [1, 2, 3])
def test_lambda_bijects_compact_onto_fractal(frac, r):
    cx, cy = _all_compact_coords(frac, r)
    ex, ey = maps.lambda_map(frac, r, jnp.asarray(cx), jnp.asarray(cy))
    ex, ey = np.asarray(ex), np.asarray(ey)
    n = frac.side(r)
    # all images are distinct fractal cells
    flat = ey.astype(np.int64) * n + ex
    assert len(np.unique(flat)) == frac.volume(r)
    mask = frac.mask(r)
    assert mask[ey, ex].all()


@pytest.mark.parametrize("frac", ALL_FRACTALS, ids=lambda f: f.name)
@pytest.mark.parametrize("r", [1, 2, 3])
def test_nu_inverts_lambda(frac, r):
    cx, cy = _all_compact_coords(frac, r)
    ex, ey = maps.lambda_map(frac, r, jnp.asarray(cx), jnp.asarray(cy))
    bx, by = maps.nu_map(frac, r, ex, ey)
    np.testing.assert_array_equal(np.asarray(bx), cx)
    np.testing.assert_array_equal(np.asarray(by), cy)


@pytest.mark.parametrize("frac", ALL_FRACTALS, ids=lambda f: f.name)
@pytest.mark.parametrize("r", [1, 2, 3])
def test_membership_matches_mask(frac, r):
    n = frac.side(r)
    ey, ex = np.meshgrid(np.arange(n), np.arange(n), indexing="ij")
    got = maps.is_fractal(frac, r, jnp.asarray(ex.reshape(-1)),
                          jnp.asarray(ey.reshape(-1)))
    want = frac.mask(r)[ey.reshape(-1), ex.reshape(-1)] > 0
    np.testing.assert_array_equal(np.asarray(got), want)


# -------------------------------------------------- scalar spec equality
@pytest.mark.parametrize("frac", ALL_FRACTALS, ids=lambda f: f.name)
def test_vectorised_matches_scalar_spec(frac):
    r = 3
    cx, cy = _all_compact_coords(frac, r)
    ex, ey = maps.lambda_map(frac, r, jnp.asarray(cx), jnp.asarray(cy))
    for i in range(0, len(cx), max(1, len(cx) // 37)):
        sx, sy = maps.lambda_map_scalar(frac, r, int(cx[i]), int(cy[i]))
        assert (int(ex[i]), int(ey[i])) == (sx, sy)
        nx, ny = maps.nu_map_scalar(frac, r, sx, sy)
        assert (nx, ny) == (int(cx[i]), int(cy[i]))


# ------------------------------------------------------- MXU matmul encodings
@pytest.mark.parametrize("frac", ALL_FRACTALS, ids=lambda f: f.name)
@pytest.mark.parametrize("r", [1, 2, 3, 4])
def test_matmul_encodings_exact(frac, r):
    if frac.volume(r) > 20000:
        r = min(r, 3)
    cx, cy = _all_compact_coords(frac, r)
    cx, cy = jnp.asarray(cx), jnp.asarray(cy)
    ex, ey = maps.lambda_map(frac, r, cx, cy)
    ex2, ey2 = maps.lambda_map_matmul(frac, r, cx, cy)
    np.testing.assert_array_equal(np.asarray(ex), np.asarray(ex2))
    np.testing.assert_array_equal(np.asarray(ey), np.asarray(ey2))
    nx, ny = maps.nu_map(frac, r, ex, ey)
    nx2, ny2 = maps.nu_map_matmul(frac, r, ex, ey)
    np.testing.assert_array_equal(np.asarray(nx), np.asarray(nx2))
    np.testing.assert_array_equal(np.asarray(ny), np.asarray(ny2))


# ----------------------------------------------------------- property tests
@st.composite
def fractal_r_coord(draw):
    frac = draw(st.sampled_from(ALL_FRACTALS))
    # keep volumes moderate: k^r <= ~1e5
    max_r = max(1, int(np.floor(np.log(1e5) / np.log(frac.k))))
    r = draw(st.integers(min_value=1, max_value=min(max_r, 16)))
    rows, cols = frac.compact_dims(r)
    cx = draw(st.integers(min_value=0, max_value=cols - 1))
    cy = draw(st.integers(min_value=0, max_value=rows - 1))
    return frac, r, cx, cy


@given(fractal_r_coord())
@settings(max_examples=200, deadline=None)
def test_property_nu_inverts_lambda_scalar(args):
    frac, r, cx, cy = args
    ex, ey = maps.lambda_map_scalar(frac, r, cx, cy)
    n = frac.side(r)
    assert 0 <= ex < n and 0 <= ey < n
    assert maps.is_fractal_scalar(frac, r, ex, ey)
    nx, ny = maps.nu_map_scalar(frac, r, ex, ey)
    assert (nx, ny) == (cx, cy)


@given(fractal_r_coord())
@settings(max_examples=100, deadline=None)
def test_property_matmul_matches_scalar(args):
    frac, r, cx, cy = args
    ex, ey = maps.lambda_map_scalar(frac, r, cx, cy)
    ex2, ey2 = maps.lambda_map_matmul(frac, r, jnp.asarray([cx]),
                                      jnp.asarray([cy]))
    assert (int(ex2[0]), int(ey2[0])) == (ex, ey)
    nx, ny = maps.nu_map_scalar(frac, r, ex, ey)
    nx2, ny2 = maps.nu_map_matmul(frac, r, jnp.asarray([ex]),
                                  jnp.asarray([ey]))
    assert (int(nx2[0]), int(ny2[0])) == (nx, ny)


@given(st.integers(min_value=1, max_value=18))
@settings(max_examples=30, deadline=None)
def test_property_sierpinski_deep_levels_roundtrip(r):
    """Deep-level roundtrip on random corner-ish coords (no O(k^r) scan)."""
    frac = fractals.SIERPINSKI
    rows, cols = frac.compact_dims(r)
    rng = np.random.default_rng(r)
    cx = rng.integers(0, cols, size=16).astype(np.int32)
    cy = rng.integers(0, rows, size=16).astype(np.int32)
    ex, ey = maps.lambda_map(frac, r, jnp.asarray(cx), jnp.asarray(cy))
    bx, by = maps.nu_map(frac, r, ex, ey)
    np.testing.assert_array_equal(np.asarray(bx), cx)
    np.testing.assert_array_equal(np.asarray(by), cy)
    # matmul form stays exact at depth (fp32 < 2**24 products)
    ex2, ey2 = maps.lambda_map_matmul(frac, r, jnp.asarray(cx),
                                      jnp.asarray(cy))
    np.testing.assert_array_equal(np.asarray(ex), np.asarray(ex2))
    np.testing.assert_array_equal(np.asarray(ey), np.asarray(ey2))
