"""Per-kernel allclose tests: nu_map / lambda_map Pallas kernels (interpret
mode) vs the pure-jnp oracles, swept over fractals, levels and batch shapes.
Integer maps must be *exact*."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import fractals, maps
from repro.kernels import ops, ref

ALL_FRACTALS = list(fractals.REGISTRY.values())


def _random_expanded_coords(frac, r, shape, seed, spill=2):
    """Random expanded coords, including out-of-bounds and hole positions."""
    n = frac.side(r)
    rng = np.random.default_rng(seed)
    ex = rng.integers(-spill, n + spill, size=shape).astype(np.int32)
    ey = rng.integers(-spill, n + spill, size=shape).astype(np.int32)
    return jnp.asarray(ex), jnp.asarray(ey)


@pytest.mark.parametrize("frac", ALL_FRACTALS, ids=lambda f: f.name)
@pytest.mark.parametrize("r", [1, 2, 4])
@pytest.mark.parametrize("shape", [(7,), (256,), (3, 130)])
def test_nu_kernel_exact(frac, r, shape):
    ex, ey = _random_expanded_coords(frac, r, shape, seed=r * 100 + len(shape))
    cx_k, cy_k, valid_k = ops.nu_map_tc(frac, r, ex, ey, interpret=True)
    cx_r, cy_r, valid_r = ref.nu_ref(frac, r, ex, ey)
    np.testing.assert_array_equal(np.asarray(valid_k), np.asarray(valid_r))
    m = np.asarray(valid_r)
    np.testing.assert_array_equal(np.asarray(cx_k)[m], np.asarray(cx_r)[m])
    np.testing.assert_array_equal(np.asarray(cy_k)[m], np.asarray(cy_r)[m])


@pytest.mark.parametrize("frac", ALL_FRACTALS, ids=lambda f: f.name)
@pytest.mark.parametrize("r", [1, 2, 4])
@pytest.mark.parametrize("shape", [(5,), (256,), (2, 300)])
def test_lambda_kernel_exact(frac, r, shape):
    rows, cols = frac.compact_dims(r)
    rng = np.random.default_rng(r * 7 + len(shape))
    cx = jnp.asarray(rng.integers(0, cols, size=shape).astype(np.int32))
    cy = jnp.asarray(rng.integers(0, rows, size=shape).astype(np.int32))
    ex_k, ey_k = ops.lambda_map_tc(frac, r, cx, cy, interpret=True)
    ex_r, ey_r = ref.lambda_ref(frac, r, cx, cy)
    np.testing.assert_array_equal(np.asarray(ex_k), np.asarray(ex_r))
    np.testing.assert_array_equal(np.asarray(ey_k), np.asarray(ey_r))


def test_kernels_roundtrip_deep_level():
    """lambda kernel -> nu kernel roundtrip at a deep level (r=16)."""
    frac, r = fractals.SIERPINSKI, 16
    rows, cols = frac.compact_dims(r)
    rng = np.random.default_rng(0)
    cx = jnp.asarray(rng.integers(0, cols, size=512).astype(np.int32))
    cy = jnp.asarray(rng.integers(0, rows, size=512).astype(np.int32))
    ex, ey = ops.lambda_map_tc(frac, r, cx, cy, interpret=True)
    bx, by, valid = ops.nu_map_tc(frac, r, ex, ey, interpret=True)
    assert bool(jnp.all(valid))
    np.testing.assert_array_equal(np.asarray(bx), np.asarray(cx))
    np.testing.assert_array_equal(np.asarray(by), np.asarray(cy))


def test_nu_kernel_matches_matmul_reference():
    """Kernel agrees with the non-Pallas MXU formulation (same encoding)."""
    frac, r = fractals.CARPET, 3
    n = frac.side(r)
    ey, ex = np.meshgrid(np.arange(n), np.arange(n), indexing="ij")
    ex = jnp.asarray(ex.reshape(-1).astype(np.int32))
    ey = jnp.asarray(ey.reshape(-1).astype(np.int32))
    valid = maps.is_fractal(frac, r, ex, ey)
    cx_k, cy_k, valid_k = ops.nu_map_tc(frac, r, ex, ey, interpret=True)
    np.testing.assert_array_equal(np.asarray(valid_k), np.asarray(valid))
    cx_m, cy_m = maps.nu_map_matmul(frac, r, ex, ey)
    m = np.asarray(valid)
    np.testing.assert_array_equal(np.asarray(cx_k)[m], np.asarray(cx_m)[m])
    np.testing.assert_array_equal(np.asarray(cy_k)[m], np.asarray(cy_m)[m])
