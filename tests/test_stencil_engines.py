"""Cross-engine equivalence: BB (classic), lambda-only [7], Squeeze cell-level
and Squeeze block-level must produce identical game-of-life trajectories on
the fractal, for several NBB fractals and levels (paper Section 4's setup)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import fractals
from repro.core.baselines import BBEngine, LambdaEngine
from repro.core.compact import BlockLayout
from repro.core.stencil import SqueezeBlockEngine, SqueezeCellEngine

CASES = [
    (fractals.SIERPINSKI, 5, 2),   # rho = 4
    (fractals.SIERPINSKI, 6, 3),   # rho = 8
    (fractals.CARPET, 3, 1),       # rho = 3
    (fractals.VICSEK, 3, 1),
    (fractals.EMPTY_BOTTLES, 3, 1),
    (fractals.CHANDELIER, 3, 1),
]


@pytest.mark.parametrize("frac,r,m", CASES,
                         ids=[f"{f.name}-r{r}-m{m}" for f, r, m in CASES])
def test_engines_agree(frac, r, m):
    steps = 6
    bb = BBEngine(frac, r)
    lam = LambdaEngine(frac, r)
    cell = SqueezeCellEngine(frac, r)
    block = SqueezeBlockEngine(BlockLayout(frac, r, m))

    e0 = bb.init_random(seed=7)
    s_bb = e0
    s_lam = e0
    s_cell = cell.init_random(seed=7)
    s_blk = block.init_random(seed=7)

    # initial states describe the same fractal configuration
    np.testing.assert_array_equal(np.asarray(cell.to_expanded(s_cell)),
                                  np.asarray(e0))
    np.testing.assert_array_equal(np.asarray(block.to_expanded(s_blk)),
                                  np.asarray(e0))

    for step in range(steps):
        s_bb = bb.step(s_bb)
        s_lam = lam.step(s_lam)
        s_cell = cell.step(s_cell)
        s_blk = block.step(s_blk)
        np.testing.assert_array_equal(
            np.asarray(s_lam), np.asarray(s_bb),
            err_msg=f"lambda-engine diverged at step {step}")
        np.testing.assert_array_equal(
            np.asarray(cell.to_expanded(s_cell)), np.asarray(s_bb),
            err_msg=f"squeeze-cell diverged at step {step}")
        np.testing.assert_array_equal(
            np.asarray(block.to_expanded(s_blk)), np.asarray(s_bb),
            err_msg=f"squeeze-block diverged at step {step}")


def test_run_matches_iterated_step():
    frac, r = fractals.SIERPINSKI, 5
    eng = SqueezeCellEngine(frac, r)
    s = eng.init_random(seed=3)
    manual = s
    for _ in range(5):
        manual = eng.step(manual)
    looped = eng.run(s, 5)
    np.testing.assert_array_equal(np.asarray(looped), np.asarray(manual))


def test_activity_is_nontrivial():
    """Guard against the degenerate all-dead fixed point masking bugs."""
    frac, r = fractals.SIERPINSKI, 6
    eng = SqueezeCellEngine(frac, r)
    s = eng.init_random(seed=11)
    s5 = eng.run(s, 5)
    assert int(jnp.sum(s5)) > 0
    assert not np.array_equal(np.asarray(s5), np.asarray(s))


def test_memory_accounting_matches_paper_structure():
    """Compact memory = k^r; BB memory = n^2; block level adds the constant
    micro-fractal overhead (paper Table 2 trend: MRF shrinks as rho grows)."""
    frac, r = fractals.SIERPINSKI, 10
    bb = BBEngine(frac, r).memory_bytes()
    assert bb == frac.side(r) ** 2
    cell = SqueezeCellEngine(frac, r).memory_bytes()
    assert cell == frac.volume(r)
    last = cell
    for m in (1, 2, 3):
        blk = SqueezeBlockEngine(BlockLayout(frac, r, m)).memory_bytes()
        assert blk >= last  # MRF decreases monotonically with rho
        assert blk <= bb
        last = blk
