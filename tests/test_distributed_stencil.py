"""Multi-device (8 placeholder CPU devices) distributed compact stencil
SMOKE: k-fused strip halo exchange + shard-local kernels vs the
single-device engine on a real 8-shard mesh. The full parity matrix
(workload x k x kind) is in-process in test_distributed_fused.py.

Runs in a subprocess so --xla_force_host_platform_device_count never leaks
into this process (smoke tests must see 1 device)."""
import os
import pathlib
import subprocess
import sys


def test_distributed_engine_matches_single_device():
    script = pathlib.Path(__file__).parent / "_distributed_check.py"
    env = dict(os.environ)
    repo = pathlib.Path(__file__).resolve().parents[1]
    env["PYTHONPATH"] = str(repo / "src") + os.pathsep + env.get(
        "PYTHONPATH", "")
    out = subprocess.run([sys.executable, str(script)], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, f"stdout:\n{out.stdout}\nstderr:\n{out.stderr}"
    assert "DISTRIBUTED_OK" in out.stdout
