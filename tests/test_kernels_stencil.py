"""Fused block-level stencil kernels vs the jnp oracle (life_blocks_ref),
and end-to-end vs the BB engine through expanded space."""
import numpy as np
import pytest

from repro.core import fractals
from repro.core.baselines import BBEngine
from repro.core.compact import BlockLayout
from repro.core.stencil import SqueezeBlockEngine
from repro.kernels import ops, ref

CASES = [
    (fractals.SIERPINSKI, 5, 2),   # rho=4
    (fractals.SIERPINSKI, 6, 3),   # rho=8
    (fractals.CARPET, 3, 1),       # rho=3
    (fractals.VICSEK, 3, 1),
]
IDS = [f"{f.name}-r{r}-m{m}" for f, r, m in CASES]


STEPS = {"blocks": ops.life_step_blocks, "strips": ops.life_step_strips,
         "fused": ops.life_step_fused, "mxu": ops.stencil_step_mxu}


@pytest.mark.parametrize("frac,r,m", CASES, ids=IDS)
@pytest.mark.parametrize("variant", ["blocks", "strips", "fused", "mxu"])
def test_stencil_kernel_matches_oracle(frac, r, m, variant):
    layout = BlockLayout(frac, r, m)
    eng = SqueezeBlockEngine(layout)
    state = eng.init_random(seed=5)
    step = STEPS[variant]
    for i in range(3):
        want = ref.life_blocks_ref(layout, state)
        got = step(layout, state, interpret=True)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want),
                                      err_msg=f"{variant} step {i}")
        state = got


@pytest.mark.parametrize("variant", ["blocks", "strips", "fused", "mxu"])
def test_stencil_kernel_matches_bb_end_to_end(variant):
    frac, r, m = fractals.SIERPINSKI, 6, 2
    layout = BlockLayout(frac, r, m)
    bb = BBEngine(frac, r)
    step = STEPS[variant]

    s_e = bb.init_random(seed=9)
    s_b = layout.from_expanded(s_e)
    for i in range(4):
        s_e = bb.step(s_e)
        s_b = step(layout, s_b, interpret=True)
        np.testing.assert_array_equal(
            np.asarray(layout.to_expanded(s_b)), np.asarray(s_e),
            err_msg=f"{variant} diverged from BB at step {i}")


def test_variants_agree_many_steps():
    frac, r, m = fractals.CARPET, 3, 1
    layout = BlockLayout(frac, r, m)
    eng = SqueezeBlockEngine(layout)
    s1 = eng.init_random(seed=2)
    s2 = s1
    s3 = s1
    s4 = s1
    for _ in range(10):
        s1 = ops.life_step_blocks(layout, s1, interpret=True)
        s2 = ops.life_step_strips(layout, s2, interpret=True)
        s3 = ops.life_step_fused(layout, s3, interpret=True)
        s4 = ops.stencil_step_mxu(layout, s4, interpret=True)
    np.testing.assert_array_equal(np.asarray(s1), np.asarray(s2))
    np.testing.assert_array_equal(np.asarray(s1), np.asarray(s3))
    np.testing.assert_array_equal(np.asarray(s1), np.asarray(s4))
