"""The XLA online-softmax (chunked) attention path must match the direct
softmax path exactly (same math, different schedule) across masks."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import attention as A
from repro.models.config import LayerSpec, ModelConfig


def _cfg(**over):
    base = dict(name="t", d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
                d_ff=128, vocab=128, unit=(LayerSpec(kind="attn"),),
                n_units=1, dtype="float32")
    base.update(over)
    return ModelConfig(**base)


def _qkv(b, s, h, kvh, hd, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    return (jax.random.normal(ks[0], (b, s, h, hd)),
            jax.random.normal(ks[1], (b, s, kvh, hd)),
            jax.random.normal(ks[2], (b, s, kvh, hd)))


@pytest.mark.parametrize("window", [None, 700],
                         ids=["global", "windowed"])
@pytest.mark.parametrize("softcap", [None, 30.0], ids=["nocap", "softcap"])
def test_chunked_matches_direct(window, softcap):
    cfg = _cfg(attn_softcap=softcap)
    s = 2048  # above threshold when squared
    q, k, v = _qkv(1, s, 4, 2, 16)
    qpos = jnp.arange(s)
    mask = qpos[None, None, :] <= qpos[None, :, None]
    if window is not None:
        mask = mask & (qpos[None, None, :] > qpos[None, :, None] - window)
    direct = A._sdpa(q, k, v, mask, cfg)
    chunked = A._sdpa_chunked(q, k, v, cfg, q0=0, k0=0, causal=True,
                              window=window)
    np.testing.assert_allclose(np.asarray(chunked), np.asarray(direct),
                               rtol=2e-5, atol=2e-5)


def test_chunked_respects_position_offset():
    cfg = _cfg()
    s = 2048
    q, k, v = _qkv(1, s, 4, 2, 16, seed=1)
    a = A._sdpa_chunked(q, k, v, cfg, q0=0, k0=0, causal=True, window=None)
    b = A._sdpa_chunked(q, k, v, cfg, q0=1000, k0=1000, causal=True,
                        window=None)
    # same relative positions -> identical outputs
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5,
                               atol=1e-5)


def test_chunked_is_differentiable():
    cfg = _cfg()
    s = 2048
    q, k, v = _qkv(1, s, 4, 2, 16, seed=2)

    def f(q):
        return jnp.sum(A._sdpa_chunked(q, k, v, cfg, q0=0, k0=0,
                                       causal=True, window=None) ** 2)
    g = jax.grad(f)(q)
    assert bool(jnp.all(jnp.isfinite(g)))
    assert float(jnp.max(jnp.abs(g))) > 0


def test_apply_attn_uses_chunked_above_threshold():
    """Full-layer equivalence across the threshold boundary: a config
    evaluated at S=2100 (chunked) equals a manual direct computation."""
    cfg = _cfg()
    spec = LayerSpec(kind="attn")
    key = jax.random.PRNGKey(3)
    p = A.init_attn(key, dataclasses.replace(cfg))
    x = jax.random.normal(jax.random.fold_in(key, 1), (1, 2100, 64))
    out, _ = A.apply_attn(p, x, cfg, spec, 0)
    assert out.shape == (1, 2100, 64)
    assert bool(jnp.all(jnp.isfinite(out)))
    # spot-check the last position against a small-window recompute
    q, k, v = A._qkv(p, x, cfg, jnp.arange(2100)[None])
    mask = (jnp.arange(2100)[None, None, :]
            <= jnp.arange(2100)[None, :, None])
    direct = A._sdpa(q, k, v, mask, cfg)
    direct_out = jnp.einsum("bshk,hkd->bsd", direct, p["wo"])
    np.testing.assert_allclose(np.asarray(out), np.asarray(direct_out),
                               rtol=2e-4, atol=2e-4)
