"""Fault tolerance end-to-end: kill training mid-run, restart from the
latest checkpoint, assert the final parameters are BIT-EXACT vs an
uninterrupted run (stateless data pipeline + atomic checkpoints + exact
restore). Also: preemption-signal checkpointing and the watchdog."""
import jax
import numpy as np
import pytest

from repro import configs
from repro.data.pipeline import SyntheticMarkov
from repro.launch.train import train
from repro.optim import adamw
from repro.runtime.fault import (PreemptionHandler, SimulatedFailure,
                                 Watchdog, run_with_restarts)


def _setup(tmp_path, name):
    cfg = configs.get_smoke_config("smollm-135m")
    opt_cfg = adamw.AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=12,
                                weight_decay=0.0)
    data = SyntheticMarkov(vocab=cfg.vocab, seq_len=16, global_batch=2,
                           seed=3)
    return cfg, opt_cfg, data, str(tmp_path / name)


def _final_params(cfg, opt_cfg, data, ckpt_dir, **kw):
    from repro.checkpoint.manager import CheckpointManager
    from repro.models import model as model_lib
    res = train(cfg, opt_cfg, data, steps=12, ckpt_dir=ckpt_dir,
                ckpt_every=4, log_every=0, **kw)
    mgr = CheckpointManager(ckpt_dir)
    params = model_lib.init_params(jax.random.PRNGKey(0), cfg)
    opt = adamw.init(opt_cfg, params)
    state = mgr.restore({"params": params, "opt": opt})
    return res, state["params"]


def test_kill_and_restart_is_bit_exact(tmp_path):
    cfg, opt_cfg, data, d1 = _setup(tmp_path, "uninterrupted")
    _, clean = _final_params(cfg, opt_cfg, data, d1)

    d2 = str(tmp_path / "interrupted")

    calls = {"n": 0}

    def make_run():
        calls["n"] += 1
        # first attempt dies after step 6 (last checkpoint at step 4)
        fail_at = 6 if calls["n"] == 1 else None
        res = train(cfg, opt_cfg, data, steps=12, ckpt_dir=d2,
                    ckpt_every=4, fail_at=fail_at, log_every=0)
        return res.step

    final_step = run_with_restarts(make_run, max_restarts=2)
    assert final_step == 12
    assert calls["n"] == 2  # one failure, one successful resume

    from repro.checkpoint.manager import CheckpointManager
    from repro.models import model as model_lib
    mgr = CheckpointManager(d2)
    params = model_lib.init_params(jax.random.PRNGKey(0), cfg)
    opt = adamw.init(opt_cfg, params)
    restarted = mgr.restore({"params": params, "opt": opt})["params"]

    for a, b in zip(jax.tree.leaves(clean), jax.tree.leaves(restarted)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_restart_resumes_from_latest_step(tmp_path):
    cfg, opt_cfg, data, d = _setup(tmp_path, "resume")
    res1 = train(cfg, opt_cfg, data, steps=8, ckpt_dir=d, ckpt_every=4,
                 log_every=0)
    assert res1.restored_from is None
    res2 = train(cfg, opt_cfg, data, steps=12, ckpt_dir=d, ckpt_every=4,
                 log_every=0)
    assert res2.restored_from == 8
    assert len(res2.losses) == 4  # only steps 8..11 executed


def test_preemption_checkpoints_and_exits(tmp_path):
    cfg, opt_cfg, data, d = _setup(tmp_path, "preempt")
    handler = PreemptionHandler(install=False)

    def on_step(step, metrics):
        if step == 5:
            handler.request()  # simulated SIGTERM

    res = train(cfg, opt_cfg, data, steps=12, ckpt_dir=d, ckpt_every=100,
                preemption=handler, on_step=on_step, log_every=0)
    assert res.step == 6  # exited right after the requested step
    from repro.checkpoint.manager import CheckpointManager
    assert CheckpointManager(d).latest_step() == 6


def test_supervisor_gives_up_after_max_restarts():
    def always_fails():
        raise SimulatedFailure("boom")
    with pytest.raises(SimulatedFailure):
        run_with_restarts(always_fails, max_restarts=2)


def test_backoff_delays_deterministic_jittered_capped():
    import itertools

    from repro.runtime.fault import backoff_delays
    a = list(itertools.islice(
        backoff_delays(base_s=0.1, cap_s=0.5, seed=7), 8))
    b = list(itertools.islice(
        backoff_delays(base_s=0.1, cap_s=0.5, seed=7), 8))
    assert a == b  # same seed, same schedule
    c = list(itertools.islice(
        backoff_delays(base_s=0.1, cap_s=0.5, seed=8), 8))
    assert a != c  # different seed decorrelates
    # full jitter stays within [raw/2, raw], raw capped at cap_s
    for i, d in enumerate(a):
        raw = min(0.5, 0.1 * 2 ** i)
        assert raw / 2 <= d <= raw
    assert max(a) <= 0.5


def test_run_with_restarts_sleeps_backoff_schedule():
    from repro.runtime.fault import backoff_delays
    calls = {"n": 0}
    slept = []

    def flaky():
        calls["n"] += 1
        if calls["n"] <= 3:
            raise SimulatedFailure("transient")
        return 99

    out = run_with_restarts(flaky, max_restarts=5, backoff_base_s=0.05,
                            backoff_cap_s=1.0, backoff_seed=11,
                            _sleep=slept.append)
    assert out == 99
    import itertools
    want = list(itertools.islice(
        backoff_delays(base_s=0.05, cap_s=1.0, seed=11), 3))
    assert slept == want  # the documented deterministic schedule


def test_run_with_restarts_wall_clock_give_up():
    slept = []

    def always_fails():
        raise SimulatedFailure("boom")

    with pytest.raises(SimulatedFailure):
        run_with_restarts(always_fails, max_restarts=10 ** 6,
                          max_elapsed_s=0.0, _sleep=slept.append)
    assert slept == []  # gave up before the first backoff sleep


def test_preemption_handler_restores_prior_handler():
    import signal

    seen = []

    def custom(signum, frame):
        seen.append(signum)

    prev = signal.signal(signal.SIGTERM, custom)
    try:
        h = PreemptionHandler()  # installs over `custom`
        assert signal.getsignal(signal.SIGTERM) == h._handler
        import os
        os.kill(os.getpid(), signal.SIGTERM)
        assert h.requested
        # delivery chains to the displaced trap (user traps still fire)
        assert seen == [signal.SIGTERM]
        h.uninstall()
        assert signal.getsignal(signal.SIGTERM) == custom
        h.uninstall()  # idempotent
        assert signal.getsignal(signal.SIGTERM) == custom
    finally:
        signal.signal(signal.SIGTERM, prev)


def test_preemption_handlers_nest_and_chain():
    """The serving layer and an elastic distributed run may each hold a
    handler at once: the signal must reach BOTH, and LIFO uninstall must
    restore the originals."""
    import os
    import signal

    before = {s: signal.getsignal(s)
              for s in (signal.SIGTERM, signal.SIGUSR1)}
    try:
        outer = PreemptionHandler()
        inner = PreemptionHandler()  # nested on top
        assert signal.getsignal(signal.SIGTERM) == inner._handler
        os.kill(os.getpid(), signal.SIGTERM)
        assert inner.requested and outer.requested  # chained delivery
        inner.uninstall()
        assert signal.getsignal(signal.SIGTERM) == outer._handler
        outer.uninstall()
        for s, h in before.items():
            assert signal.getsignal(s) == h
    finally:
        for s, h in before.items():
            signal.signal(s, h)


def test_preemption_handler_out_of_order_uninstall_is_safe():
    """An outer handler uninstalled FIRST must not clobber the inner
    trap still live on top of it (the regression: uninstall used to
    restore unconditionally, silently disarming the inner handler)."""
    import signal

    before = {s: signal.getsignal(s)
              for s in (signal.SIGTERM, signal.SIGUSR1)}
    try:
        outer = PreemptionHandler()
        inner = PreemptionHandler()
        outer.uninstall()  # out of order: forfeits its restore
        assert signal.getsignal(signal.SIGTERM) == inner._handler
        assert signal.getsignal(signal.SIGUSR1) == inner._handler
        inner.uninstall()
        # the inner restores what it displaced — the outer's trap
        # function, which only flags the already-dismissed instance
        assert signal.getsignal(signal.SIGTERM) == outer._handler
    finally:
        for s, h in before.items():
            signal.signal(s, h)


def test_preemption_handler_context_manager_uninstalls():
    import signal
    before = signal.getsignal(signal.SIGTERM)
    with PreemptionHandler() as h:
        assert signal.getsignal(signal.SIGTERM) == h._handler
    assert signal.getsignal(signal.SIGTERM) == before


def test_watchdog_flags_stragglers():
    import time
    wd = Watchdog(straggler_factor=3.0)
    for _ in range(8):
        wd.start_step()
        time.sleep(0.002)
        wd.end_step()
    assert wd.stragglers == 0
    wd.start_step()
    time.sleep(0.05)
    wd.end_step()
    assert wd.stragglers == 1
