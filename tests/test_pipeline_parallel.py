"""GPipe pipeline parallelism over the pod axis (subprocess: 4 devices)."""
import os
import pathlib
import subprocess
import sys


def test_pipeline_matches_sequential():
    script = pathlib.Path(__file__).parent / "_pp_check.py"
    env = dict(os.environ)
    repo = pathlib.Path(__file__).resolve().parents[1]
    env["PYTHONPATH"] = str(repo / "src") + os.pathsep + env.get(
        "PYTHONPATH", "")
    out = subprocess.run([sys.executable, str(script)], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, f"stdout:\n{out.stdout}\nstderr:\n{out.stderr}"
    assert "PP_OK" in out.stdout
