"""The 3D performance stack: block layout geometry, fused k-stepping,
Pallas kernels, cached runs, and the batched runner in 3D.

Covers: depth-k 3D halo geometry vs expanded-space windows (offset
tables, halo masks, pad_with_halo_k), the cross-engine parity matrix
(bb3d / cell3d / block3d / pallas-3d / pallas-3d-mxu) x workload
(LIFE3D bit-exact, HEAT3D allclose) x k including the remainder path
and k > rho across block-level holes, the z-slab MXU weight
factorization, buffer donation + the cached-jit (no-retrace) run fix
for ``Squeeze3DEngine``, and the batched runner's 3D dispatch with k in
the cache key.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import fractals3d as f3
from repro.core.compact3d import BlockLayout3D
from repro.core.stencil import make_engine
from repro.kernels import squeeze_stencil3d as k3
from repro.workloads import HEAT3D, LIFE3D, BatchedRunner
from repro.workloads.base import MOORE3_DIRS

ALL_WORKLOADS = [LIFE3D, HEAT3D]
WL_IDS = [w.name for w in ALL_WORKLOADS]

CASES = [
    (f3.SIERPINSKI3D, 4, 1),   # rho = 2, holes everywhere
    (f3.MENGER, 2, 1),         # rho = 3, interior holes
]
CASE_IDS = [f"{f.name}-r{r}-m{m}" for f, r, m in CASES]

BLOCK_KINDS = ["block3d", "pallas-3d", "pallas-3d-mxu"]


def _tol(wl):
    return dict(rtol=0, atol=0) if wl.dtype == jnp.uint8 \
        else dict(rtol=1e-5, atol=1e-5)


def _single_steps(eng, state, n):
    for _ in range(n):
        state = eng.step(state)
    return state


def _random_block_state(layout, seed=0):
    rng = np.random.default_rng(seed)
    rho = layout.rho
    s = rng.integers(0, 9, (layout.n_blocks, rho, rho, rho))
    return jnp.asarray(s.astype(np.float32)
                       * np.asarray(layout.micro_mask))


# ------------------------------------------------------ depth-k geometry
@pytest.mark.parametrize("frac,r,m", CASES, ids=CASE_IDS)
@pytest.mark.parametrize("k", [1, 2, 4])
def test_halo3_geometry_matches_expanded_windows(frac, r, m, k):
    """halo_mask(k) and pad_with_halo_k(s, k) must equal the depth-k
    window around each block cut from zero-padded expanded space — at
    every depth, including k > rho (multi-ring offset tables) and
    across out-of-fractal (ghost) regions."""
    layout = BlockLayout3D(frac, r, m)
    rho = layout.rho
    s = _random_block_state(layout, seed=1)
    mask_pad = np.pad(np.asarray(frac.mask(r)), k)
    state_pad = np.pad(np.asarray(layout.to_expanded(s)), k)
    hmask = layout.halo_mask(k)
    padded = np.asarray(layout.pad_with_halo_k(s, k))
    w = rho + 2 * k
    for b, (ox, oy, oz) in enumerate(layout.block_origin_expanded):
        np.testing.assert_array_equal(
            hmask[b], mask_pad[oz:oz + w, oy:oy + w, ox:ox + w],
            err_msg=f"halo_mask block {b}")
        np.testing.assert_array_equal(
            padded[b], state_pad[oz:oz + w, oy:oy + w, ox:ox + w],
            err_msg=f"pad_with_halo_k block {b}")


def test_offset_table3_depth1_is_neighbor_table():
    layout = BlockLayout3D(f3.MENGER, 2, 1)
    assert layout.halo_offsets(layout.rho) == MOORE3_DIRS
    np.testing.assert_array_equal(layout.offset_table(2),
                                  layout.neighbor_table)
    assert layout.neighbor_table.shape == (layout.n_blocks, 26)


def test_roundtrip_and_memory():
    layout = BlockLayout3D(f3.SIERPINSKI3D, 4, 2)
    s = _random_block_state(layout, seed=2)
    np.testing.assert_array_equal(
        np.asarray(layout.from_expanded(layout.to_expanded(s))),
        np.asarray(s))
    # block state stores expanded rho^3 micro-cubes (micro-holes incl.):
    # n_blocks * rho^3, never less than the compact cell count
    assert layout.memory_bytes() == layout.n_blocks * layout.rho ** 3
    assert layout.memory_bytes() >= layout.frac.volume(layout.r)
    # the memory win vs the bounding volume is the block-level MRF
    bb = layout.frac.side(layout.r) ** 3
    assert bb / layout.memory_bytes() == layout.frac.mrf(layout.r_b)


def test_weight_factors3_reconstruct_exactly():
    """Every z-plane's rank-1 terms must rebuild that plane of the
    (3,3,3) weight tensor exactly — the z-slab MXU formulation's
    correctness precondition."""
    for wl in ALL_WORKLOADS:
        w3 = wl.weights3x3x3
        for dz in (-1, 0, 1):
            plane = w3[dz + 1]
            recon = np.zeros((3, 3), np.float64)
            for row, col in wl.weight_factors3[dz + 1]:
                recon += np.outer(row, col)
            np.testing.assert_allclose(recon, plane, rtol=0, atol=1e-12,
                                       err_msg=f"{wl.name} dz={dz}")
        # no plane of a live workload may be silently dropped
        assert any(wl.weight_factors3), wl.name


# ------------------------------------------------ cross-engine parity
@pytest.mark.parametrize("frac,r,m", CASES, ids=CASE_IDS)
@pytest.mark.parametrize("wl", ALL_WORKLOADS, ids=WL_IDS)
@pytest.mark.parametrize("kind",
                         ["cell3d", "block3d", "pallas-3d", "pallas-3d-mxu"])
def test_3d_engines_match_bb_oracle(frac, r, m, wl, kind):
    bb = make_engine("bb3d", frac, r, workload=wl)
    eng = make_engine(kind, frac, r, m, workload=wl)
    s_bb = bb.init_random(seed=5)
    s = eng.init_random(seed=5)
    np.testing.assert_allclose(np.asarray(eng.to_expanded(s)),
                               np.asarray(s_bb), **_tol(wl))
    for step in range(3):
        s_bb = bb.step(s_bb)
        s = eng.step(s)
        np.testing.assert_allclose(
            np.asarray(eng.to_expanded(s)), np.asarray(s_bb), **_tol(wl),
            err_msg=f"{kind}/{wl.name} diverged at step {step}")


@pytest.mark.parametrize("frac,r,m", CASES, ids=CASE_IDS)
@pytest.mark.parametrize("wl", ALL_WORKLOADS, ids=WL_IDS)
@pytest.mark.parametrize("kind", BLOCK_KINDS)
@pytest.mark.parametrize("k", [1, 2, "rho"])
def test_3d_step_k_matches_single_steps(frac, r, m, wl, kind, k):
    rho = frac.s ** m
    k = rho if k == "rho" else k
    blk = make_engine("block3d", frac, r, m, workload=wl)
    eng = blk if kind == "block3d" else make_engine(kind, frac, r, m,
                                                    workload=wl)
    s = blk.init_random(seed=5)
    np.testing.assert_allclose(
        np.asarray(eng.step_k(s, k)),
        np.asarray(_single_steps(blk, s, k)), **_tol(wl),
        err_msg=f"{kind}/{wl.name}/k={k}")


def test_3d_step_k_beyond_rho_multi_ring():
    """k > rho spans multiple block rings: the XLA path's offset tables
    must resolve blocks beyond holes exactly at depth > one ring."""
    frac, r, m = f3.SIERPINSKI3D, 4, 1  # rho = 2
    eng = make_engine("block3d", frac, r, m, workload=LIFE3D)
    s = eng.init_random(seed=8)
    k = eng.layout.rho + 1
    assert eng.layout.halo_block_radius(k) == 2
    np.testing.assert_array_equal(
        np.asarray(eng.step_k(s, k)),
        np.asarray(_single_steps(eng, s, k)))


@pytest.mark.parametrize("kind", BLOCK_KINDS)
@pytest.mark.parametrize("k,steps", [(2, 5), (3, 4)])
def test_3d_fused_run_remainder_path(kind, k, steps):
    frac, r, m = f3.MENGER, 2, 1  # rho = 3
    eng = make_engine(kind, frac, r, m, workload=HEAT3D, fusion_k=k)
    assert eng.effective_fusion_k == k
    s = eng.init_random(seed=9)
    np.testing.assert_allclose(
        np.asarray(eng.run(s, steps)),
        np.asarray(_single_steps(eng, s, steps)),
        rtol=1e-5, atol=1e-5, err_msg=f"{kind}/k={k}/steps={steps}")


def test_pallas3d_rejects_k_beyond_rho():
    frac, r, m = f3.SIERPINSKI3D, 4, 1  # rho = 2
    layout = BlockLayout3D(frac, r, m)
    s = jnp.zeros((layout.n_blocks, 2, 2, 2), jnp.uint8)
    with pytest.raises(ValueError, match="k <= rho"):
        k3.stencil3d_step_fused_k(layout, s, LIFE3D, k=3)
    with pytest.raises(ValueError, match="k <= rho"):
        k3.stencil3d_step_mxu_k(layout, s, LIFE3D, k=3)
    with pytest.raises(ValueError, match="fusion_k"):
        make_engine("pallas-3d", frac, r, m, workload=LIFE3D, fusion_k=3)


def test_3d_engines_reject_wrong_workloads():
    from repro.workloads import GRAY_SCOTT, HEAT
    with pytest.raises(ValueError, match="single-channel"):
        make_engine("cell3d", f3.SIERPINSKI3D, 3, workload=GRAY_SCOTT)
    with pytest.raises(ValueError, match="2D-only"):
        make_engine("block3d", f3.SIERPINSKI3D, 3, 1, workload=HEAT)


# ------------------------------------------------- cached runs / donation
def _donation_supported() -> bool:
    f = jax.jit(lambda x: x + 1.0, donate_argnums=0)
    x = jnp.zeros(16)
    f(x)
    return x.is_deleted()


def test_cell3d_run_does_not_retrace_per_step_count():
    """``Squeeze3DEngine.run`` compiles once; the step count is a traced
    loop bound (the old bare fori_loop retraced per distinct count)."""
    eng = make_engine("cell3d", f3.SIERPINSKI3D, 4, workload=LIFE3D)
    s = eng.init_random(seed=1)
    eng.run(s, 2)
    n1 = eng._run._cache_size()
    out = eng.run(s, 7)
    assert eng._run._cache_size() == n1
    np.testing.assert_array_equal(np.asarray(out),
                                  np.asarray(_single_steps(eng, s, 7)))


@pytest.mark.parametrize("kind", ["cell3d", "block3d", "pallas-3d"])
def test_3d_donated_run_consumes_input(kind):
    if not _donation_supported():
        pytest.skip("backend does not implement buffer donation")
    eng = make_engine(kind, f3.SIERPINSKI3D, 4, 1, workload=HEAT3D)
    s = eng.init_random(seed=3)
    ref = _single_steps(eng, s, 4)
    out = eng.run(s, 4, donate=True)
    assert s.is_deleted()
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


# ------------------------------------------------------- batched runner
def test_runner_dispatches_3d_states():
    frac, r, m = f3.SIERPINSKI3D, 4, 1
    runner = BatchedRunner()
    states = runner.init_batch("block3d", frac, r, seeds=range(3), m=m,
                               workload=LIFE3D)
    assert states.shape == (3, frac.volume(r - m), 2, 2, 2)
    ran = runner.run("block3d", frac, r, states, steps=5, m=m,
                     workload=LIFE3D, k=2)
    eng = runner.engine_for("block3d", frac, r, m=m, workload=LIFE3D, k=2)
    for b in range(states.shape[0]):
        np.testing.assert_array_equal(
            np.asarray(ran[b]),
            np.asarray(_single_steps(eng, states[b], 5)),
            err_msg=f"batch {b}")
    # expanded conversion is batched too
    exp = runner.to_expanded("block3d", frac, r, states, m=m,
                             workload=LIFE3D)
    assert exp.shape == (3,) + (frac.side(r),) * 3


def test_runner_3d_cache_key_includes_k(monkeypatch):
    monkeypatch.setenv("SQUEEZE_TUNING", "off")  # pin the heuristic k
    frac, r, m = f3.SIERPINSKI3D, 4, 1  # rho = 2 -> heuristic k = 2
    runner = BatchedRunner()
    e_default = runner.engine_for("block3d", frac, r, m=m, workload=LIFE3D)
    assert runner.engine_for("block3d", frac, r, m=m, workload=LIFE3D,
                             k=2) is e_default
    assert runner.stats.builds == 1
    e3 = runner.engine_for("block3d", frac, r, m=m, workload=LIFE3D, k=3)
    assert e3 is not e_default and e3.fusion_k == 3
    # non-block 3D kinds normalize k away (one slot, no fusion)
    runner.engine_for("cell3d", frac, r, workload=LIFE3D)
    runner.engine_for("cell3d", frac, r, workload=LIFE3D, k=5)
    assert runner.stats.builds == 3


def test_runner_pallas3d_step():
    frac, r, m = f3.MENGER, 2, 1
    runner = BatchedRunner()
    states = runner.init_batch("pallas-3d", frac, r, seeds=range(2), m=m,
                               workload=HEAT3D)
    got = runner.step("pallas-3d", frac, r, states, m=m, workload=HEAT3D)
    eng = runner.engine_for("pallas-3d", frac, r, m=m, workload=HEAT3D)
    for b in range(2):
        np.testing.assert_allclose(np.asarray(got[b]),
                                   np.asarray(eng.step(states[b])),
                                   rtol=1e-5, atol=1e-5)
