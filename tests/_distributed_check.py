"""Subprocess SMOKE body for the multi-device distributed-stencil test.

Run with 8 placeholder host devices (the flag must precede any jax import,
and must NOT leak into the main pytest process — see dryrun.py's same
pattern). The full parity matrix lives in-process in
tests/test_distributed_fused.py; this smoke keeps one real 8-shard mesh
in the loop: a couple of fractals, every shard-local compute backend,
fused and unfused depths, the exchange accounting, and the structural
one-all-gather-per-launch check against the lowered 8-device HLO.
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import math  # noqa: E402

import jax  # noqa: E402
import numpy as np  # noqa: E402

from repro.core import fractals  # noqa: E402
from repro.core.compact import BlockLayout  # noqa: E402
from repro.core.distributed import make_distributed_engine  # noqa: E402
from repro.core.stencil import SqueezeBlockEngine  # noqa: E402
from repro.workloads.rules import GRAY_SCOTT, LIFE  # noqa: E402


def check(frac, r, m, workload, compute, k, steps=5):
    layout = BlockLayout(frac, r, m)
    dist = make_distributed_engine(layout, workload=workload,
                                   compute=compute, fusion_k=k,
                                   interpret=True)
    local = SqueezeBlockEngine(layout, workload, fusion_k=1)

    s_dist = dist.init_random(seed=13)
    s_local = local.init_random(seed=13)
    np.testing.assert_array_equal(
        np.asarray(dist.to_dense(s_dist)), np.asarray(s_local))

    s_dist = dist.run(s_dist, steps)
    for _ in range(steps):
        s_local = local.step(s_local)
    got = np.asarray(dist.to_dense(s_dist))
    want = np.asarray(s_local)
    tag = f"{frac.name}/{workload.name}/{compute}/k={k}"
    if workload.dtype == np.uint8:
        np.testing.assert_array_equal(got, want, err_msg=tag)
    else:
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5,
                                   err_msg=tag)

    # padding blocks must stay dead
    pad = np.asarray(s_dist)[..., layout.n_blocks:, :, :]
    assert (pad == 0).all(), f"{tag}: padding blocks came alive"

    # exactly ceil(steps/k) halo all-gathers
    st = dist.exchange_stats()
    assert st.steps == steps, st
    assert st.collectives == math.ceil(steps / k), (tag, st)
    print(f"{tag}: distributed == single-device over {steps} steps, "
          f"{st.collectives} collectives")
    return dist


def main():
    assert jax.device_count() == 8, jax.devices()
    for frac, r, m in [(fractals.SIERPINSKI, 6, 2),
                       (fractals.CARPET, 3, 1)]:
        for compute in ("jnp", "fused", "mxu"):
            check(frac, r, m, LIFE, compute, k=2)
    check(fractals.SIERPINSKI, 6, 2, LIFE, "jnp", k=1)
    check(fractals.SIERPINSKI, 6, 2, GRAY_SCOTT, "mxu", k=2)

    # structural: ONE all_gather in the lowered 8-shard fused step
    layout = BlockLayout(fractals.SIERPINSKI, 6, 2)
    dist = make_distributed_engine(layout, workload=LIFE, compute="jnp",
                                   fusion_k=2, interpret=True)
    txt = dist.lowered_step_text(dist.init_random(0), 2)
    n_ag = txt.count('"stablehlo.all_gather"')
    assert n_ag == 1, f"expected 1 all_gather in the fused step, got {n_ag}"
    print("fused step lowers to exactly one all_gather")
    print("DISTRIBUTED_OK")


if __name__ == "__main__":
    main()
