"""Subprocess body for the multi-device distributed-stencil test.

Run with 8 placeholder host devices (the flag must precede any jax import,
and must NOT leak into the main pytest process — see dryrun.py's same
pattern), compares the shard_map engine against the single-device oracle.
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.core import fractals  # noqa: E402
from repro.core.compact import BlockLayout  # noqa: E402
from repro.core.distributed import make_distributed_engine  # noqa: E402
from repro.core.stencil import SqueezeBlockEngine  # noqa: E402


def main():
    assert jax.device_count() == 8, jax.devices()
    for frac, r, m in [(fractals.SIERPINSKI, 6, 2),
                       (fractals.CARPET, 3, 1),
                       (fractals.VICSEK, 4, 1)]:
        layout = BlockLayout(frac, r, m)
        dist = make_distributed_engine(layout)
        local = SqueezeBlockEngine(layout)

        s_dist = dist.init_random(seed=13)
        s_local = local.init_random(seed=13)
        np.testing.assert_array_equal(
            np.asarray(dist.to_dense(s_dist)), np.asarray(s_local))

        for step in range(5):
            s_dist = dist.step(s_dist)
            s_local = local.step(s_local)
            np.testing.assert_array_equal(
                np.asarray(dist.to_dense(s_dist)), np.asarray(s_local),
                err_msg=f"{frac.name} diverged at step {step}")

        # padding blocks must stay dead
        pad = np.asarray(s_dist)[layout.n_blocks:]
        assert (pad == 0).all(), "padding blocks came alive"

        # multi-step driver agrees with iterated step
        s2 = dist.run(dist.init_random(seed=13), 5)
        np.testing.assert_array_equal(np.asarray(dist.to_dense(s2)),
                                      np.asarray(s_local))
        print(f"{frac.name}: distributed == single-device over 5 steps")
    print("DISTRIBUTED_OK")


if __name__ == "__main__":
    main()
