"""Subprocess SMOKE body for the multi-device distributed-stencil test.

Run with 8 placeholder host devices (the flag must precede any jax import,
and must NOT leak into the main pytest process — see dryrun.py's same
pattern). The full parity matrix lives in-process in
tests/test_distributed_fused.py; this smoke keeps one real 8-shard mesh
in the loop: a couple of fractals, every shard-local compute backend,
BOTH halo-exchange modes (neighbor-only ppermute and the all-gather
fallback), fused and unfused depths, the exchange accounting, and the
structural collective checks against the lowered 8-device HLO (one
all_gather per gather launch; two collective_permutes and zero
all_gathers per p2p launch).
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import math  # noqa: E402

import jax  # noqa: E402
import numpy as np  # noqa: E402

from repro.core import fractals  # noqa: E402
from repro.core.compact import BlockLayout  # noqa: E402
from repro.core.distributed import make_distributed_engine  # noqa: E402
from repro.core.stencil import SqueezeBlockEngine  # noqa: E402
from repro.workloads.rules import GRAY_SCOTT, LIFE  # noqa: E402


def check(frac, r, m, workload, compute, k, steps=5, exchange="gather"):
    layout = BlockLayout(frac, r, m)
    dist = make_distributed_engine(layout, workload=workload,
                                   compute=compute, fusion_k=k,
                                   interpret=True, exchange=exchange)
    assert dist.exchange_mode == exchange, (dist.exchange_mode, exchange)
    local = SqueezeBlockEngine(layout, workload, fusion_k=1)

    s_dist = dist.init_random(seed=13)
    s_local = local.init_random(seed=13)
    np.testing.assert_array_equal(
        np.asarray(dist.to_dense(s_dist)), np.asarray(s_local))

    s_dist = dist.run(s_dist, steps)
    for _ in range(steps):
        s_local = local.step(s_local)
    got = np.asarray(dist.to_dense(s_dist))
    want = np.asarray(s_local)
    tag = f"{frac.name}/{workload.name}/{compute}/{exchange}/k={k}"
    if workload.dtype == np.uint8:
        np.testing.assert_array_equal(got, want, err_msg=tag)
    else:
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5,
                                   err_msg=tag)

    # dead cells (fractal holes + padding blocks, wherever the native
    # block order puts them) must stay dead
    dead = np.asarray(s_dist) * dist.dead_mask()
    assert (dead == 0).all(), f"{tag}: dead blocks came alive"

    # exactly ceil(steps/k) halo exchanges, on the right byte counter
    st = dist.exchange_stats()
    assert st.steps == steps, st
    assert st.collectives == math.ceil(steps / k), (tag, st)
    if exchange == "p2p":
        assert st.bytes_permuted > 0 and st.bytes_gathered == 0, (tag, st)
        assert st.neighbor_sends == st.collectives * 2 * 7, (tag, st)
    else:
        assert st.bytes_gathered > 0 and st.bytes_permuted == 0, (tag, st)
    print(f"{tag}: distributed == single-device over {steps} steps, "
          f"{st.collectives} collectives")
    return dist


def main():
    assert jax.device_count() == 8, jax.devices()
    for frac, r, m in [(fractals.SIERPINSKI, 6, 2),
                       (fractals.CARPET, 3, 1)]:
        for compute in ("jnp", "fused", "mxu"):
            check(frac, r, m, LIFE, compute, k=2)
    check(fractals.SIERPINSKI, 6, 2, LIFE, "jnp", k=1)
    check(fractals.SIERPINSKI, 6, 2, GRAY_SCOTT, "mxu", k=2)

    # the neighbor-only ppermute exchange: same matrix spine on p2p
    for compute in ("jnp", "fused", "mxu"):
        check(fractals.SIERPINSKI, 6, 2, LIFE, compute, k=2,
              exchange="p2p")
    check(fractals.CARPET, 3, 1, LIFE, "jnp", k=2, exchange="p2p")
    check(fractals.SIERPINSKI, 6, 2, GRAY_SCOTT, "mxu", k=2,
          exchange="p2p")

    # structural: ONE all_gather in the lowered 8-shard gather step
    layout = BlockLayout(fractals.SIERPINSKI, 6, 2)
    dist = make_distributed_engine(layout, workload=LIFE, compute="jnp",
                                   fusion_k=2, interpret=True,
                                   exchange="gather")
    txt = dist.lowered_step_text(dist.init_random(0), 2)
    n_ag = txt.count('"stablehlo.all_gather"')
    assert n_ag == 1, f"expected 1 all_gather in the fused step, got {n_ag}"
    print("gather step lowers to exactly one all_gather")

    # structural: the p2p step is all-gather-free — exactly the two
    # neighbor permute shifts
    dist = make_distributed_engine(layout, workload=LIFE, compute="jnp",
                                   fusion_k=2, interpret=True,
                                   exchange="p2p")
    txt = dist.lowered_step_text(dist.init_random(0), 2)
    n_ag = txt.count('"stablehlo.all_gather"')
    n_cp = txt.count('"stablehlo.collective_permute"')
    assert n_ag == 0, f"expected 0 all_gathers in the p2p step, got {n_ag}"
    assert n_cp == 2, f"expected 2 collective_permutes, got {n_cp}"
    print("p2p step lowers to two collective_permutes, zero all_gathers")
    print("DISTRIBUTED_OK")


if __name__ == "__main__":
    main()
