"""Gradient accumulation: accum_steps=K must match the single-shot step
(same loss, same updated params) when microbatches are balanced."""
import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.launch import specs as specs_lib
from repro.models import model as model_lib
from repro.optim import adamw


def test_accum_matches_single_shot():
    cfg = configs.get_smoke_config("smollm-135m")
    opt_cfg = adamw.AdamWConfig(lr=1e-3, warmup_steps=0, total_steps=10,
                                weight_decay=0.0)
    params = model_lib.init_params(jax.random.PRNGKey(0), cfg)
    opt1 = adamw.init(opt_cfg, params)
    opt1 = jax.tree.map(lambda a: jnp.array(a, copy=True), opt1)
    opt2 = jax.tree.map(lambda a: jnp.array(a, copy=True), opt1)

    b = 8
    tokens = jax.random.randint(jax.random.PRNGKey(1), (b, 32), 0,
                                cfg.vocab)
    labels = jax.random.randint(jax.random.PRNGKey(2), (b, 32), 0,
                                cfg.vocab)
    batch = {"tokens": tokens, "labels": labels}

    step1 = specs_lib.make_train_step(cfg, opt_cfg, accum_steps=1)
    step4 = specs_lib.make_train_step(cfg, opt_cfg, accum_steps=4)

    p1, o1, m1 = step1(params, opt1, batch)
    p4, o4, m4 = step4(params, opt2, batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m4["loss"]),
                               rtol=1e-5)
    for a, b_ in zip(jax.tree.leaves(p1), jax.tree.leaves(p4)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_),
                                   rtol=5e-4, atol=5e-6)
