"""utils/hlo.py collective parser + utils/analytic.py model sanity."""
import pytest

from repro import configs
from repro.utils import analytic, hlo


def test_collective_parser_counts_output_bytes():
    txt = """
  %x = f32[64,512]{1,0} all-reduce(%dot), channel_id=1
  %y = (f32[8,4]{1,0}, f32[8,4]{1,0}) all-gather(%a, %b), channel_id=2
  %z = bf16[128]{0} reduce-scatter(%c), channel_id=3
  %w = f32[2,2]{1,0} all-to-all(%d)
  %p = u32[16]{0} collective-permute(%e)
  %skip = f32[9]{0} add(%f, %g)
"""
    out = hlo.collective_bytes(txt)
    assert out["all-reduce"] == 64 * 512 * 4
    assert out["all-gather"] == 2 * 8 * 4 * 4
    assert out["reduce-scatter"] == 128 * 2
    assert out["all-to-all"] == 2 * 2 * 4
    assert out["collective-permute"] == 16 * 4
    assert out["total"] == sum(out[k] for k in (
        "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
        "collective-permute"))


def test_collective_parser_skips_done_counts_start():
    txt = """
  %s = f32[1024]{0} all-gather-start(%a)
  %d = f32[1024]{0} all-gather-done(%s)
"""
    out = hlo.collective_bytes(txt)
    assert out["all-gather"] == 1024 * 4  # start counted once, done skipped


def test_roofline_terms_and_bottleneck():
    r = hlo.Roofline(flops=197e12, hbm_bytes=819e9, coll_bytes=0.0,
                     n_chips=4, model_flops=4 * 197e12)
    assert abs(r.t_compute - 1.0) < 1e-9
    assert abs(r.t_memory - 1.0) < 1e-9
    assert r.t_collective == 0.0
    assert r.bottleneck in ("compute", "memory")
    assert abs(r.useful_flops_ratio - 1.0) < 1e-9
    assert abs(r.mfu_bound - 1.0) < 1e-9


@pytest.mark.parametrize("arch", list(configs.ALL_ARCHS))
@pytest.mark.parametrize("shape", ["train_4k", "decode_32k"])
def test_analytic_model_sane(arch, shape):
    cfg = configs.get_config(arch)
    mesh = analytic.MeshModel()
    roof = analytic.analytic_roofline(cfg, shape, mesh)
    assert roof.flops > 0
    assert roof.hbm_bytes > 0
    assert roof.model_flops > 0
    assert 0 < roof.mfu_bound <= 1.0, (arch, shape, roof.mfu_bound)
    if shape == "train_4k":
        # executed >= useful (remat + attention overhead)
        assert roof.flops * mesh.n_chips >= roof.model_flops * 0.95
        assert 0.3 <= roof.useful_flops_ratio <= 1.05


def test_flops_model_moe_counts_active_only():
    mix = configs.get_config("mixtral-8x22b")
    full = mix.param_count()
    active = mix.active_param_count()
    assert active < 0.45 * full  # top-2 of 8 experts + attn
    fl = analytic.flops_model(mix, "train_4k")
    assert abs(fl["useful"] - 6.0 * active * 256 * 4096) / fl["useful"] \
        < 1e-6
