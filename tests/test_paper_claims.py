"""Regression-locks on the paper's quantitative claims (EXPERIMENTS.md
§Paper-validation): Table 2 MRFs exact, Fig. 10 points within
plot-reading tolerance, r=20 capability, work-ratio growth."""
import pytest

from repro.core import fractals
from repro.core.compact import BlockLayout

TABLE2 = {1: 99.8, 2: 74.8, 4: 56.1, 8: 42.1, 16: 31.6, 32: 23.7}


@pytest.mark.parametrize("rho,paper", sorted(TABLE2.items()))
def test_table2_mrf_exact(rho, paper):
    frac, r = fractals.SIERPINSKI, 16
    bb = frac.side(r) ** 2
    if rho == 1:
        mem = frac.volume(r)
    else:
        m = rho.bit_length() - 1
        mem = BlockLayout(frac, r, m).memory_bytes()
    assert abs(bb / mem - paper) / paper < 0.005


@pytest.mark.parametrize("frac,n,paper", [
    (fractals.VICSEK, 3 ** 10, 400.0),
    (fractals.SIERPINSKI, 2 ** 16, 105.0),
    (fractals.CARPET, 3 ** 10, 3.4),
])
def test_fig10_points(frac, n, paper):
    r = frac.level_of_side(n)
    assert abs(frac.mrf(r) - paper) / paper < 0.25


def test_r20_capability_claim():
    """Paper §4.3: level 20 needs ~13-55 GB under Squeeze vs 4 TB BB
    (4-byte cells); with 1-byte cells: 1 TiB vs ~10 GiB at rho=16."""
    frac = fractals.SIERPINSKI
    bb = frac.side(20) ** 2
    sq = BlockLayout(frac, 20, 4).memory_bytes()
    assert bb / 2 ** 40 >= 1.0          # >= 1 TiB
    assert sq / 2 ** 30 < 16            # fits one accelerator's HBM
    assert 80 < bb / sq < 120           # ~100x at rho=16


def test_speedup_work_ratio_grows_with_level():
    """The driver of Fig. 13's growth: BB work / fractal work = (s^2/k)^r."""
    frac = fractals.SIERPINSKI
    ratios = [frac.side(r) ** 2 / frac.volume(r) for r in (5, 9, 13, 16)]
    assert all(b > a for a, b in zip(ratios, ratios[1:]))
    assert abs(ratios[-1] - 99.85) < 0.1
