"""BatchedRunner cache behaviour under concurrent access: the serving
layer drives one runner from several worker threads (including
abandoned hang threads racing a fresh retry), so the LRU must stay
consistent — build-once on concurrent miss, sane eviction accounting,
no lost or duplicated entries."""
import threading
import time
from concurrent.futures import ThreadPoolExecutor

import numpy as np
import pytest

from repro import obs
from repro.core import fractals
from repro.workloads import LIFE, BatchedRunner

FRAC = fractals.SIERPINSKI


def _touch(runner, r):
    return runner.engine_for("block", FRAC, r, m=1)


def test_concurrent_miss_builds_once():
    """Eight threads miss the same cold key simultaneously; exactly one
    builds, the rest wait on the build event and take the hit."""
    runner = BatchedRunner(capacity=4)
    gate = threading.Barrier(8)

    def hit():
        gate.wait()
        return _touch(runner, 4)

    with ThreadPoolExecutor(max_workers=8) as ex:
        engines = [f.result() for f in
                   [ex.submit(hit) for _ in range(8)]]
    assert runner.stats.builds == 1
    assert all(e is engines[0] for e in engines)
    assert runner.cache_size() == 1


def test_concurrent_distinct_keys_all_cached():
    runner = BatchedRunner(capacity=8)
    rs = [3, 4, 5]
    with ThreadPoolExecutor(max_workers=len(rs)) as ex:
        list(ex.map(lambda r: _touch(runner, r), rs))
    assert runner.stats.builds == len(rs)
    assert runner.cache_size() == len(rs)
    assert all(runner.is_cached("block", FRAC, r, m=1) for r in rs)


def test_eviction_under_concurrent_access():
    """Hammer a capacity-2 cache with 4 keys from 8 threads: counters
    must balance (entries = builds - evictions) and every engine the
    threads got back must still run correctly."""
    runner = BatchedRunner(capacity=2)
    rs = [3, 4, 5, 6]
    stop = threading.Event()
    errs = []

    def churn(seed):
        rng = np.random.default_rng(seed)
        try:
            while not stop.is_set():
                _touch(runner, int(rng.choice(rs)))
        except Exception as e:  # pragma: no cover - failure reporting
            errs.append(e)

    threads = [threading.Thread(target=churn, args=(i,))
               for i in range(8)]
    for t in threads:
        t.start()
    # let every key build at least once, then stop. Builds compile, so
    # a fixed-length churn window flakes on slow machines — poll the
    # counter instead (bounded by a generous deadline).
    deadline = time.monotonic() + 120.0
    while (runner.stats.builds < len(rs) and not errs
           and time.monotonic() < deadline):
        time.sleep(0.05)
    stop.set()
    for t in threads:
        t.join()
    assert not errs
    assert runner.cache_size() <= 2
    assert runner.stats.builds >= len(rs)  # misses forced rebuilds
    assert (runner.stats.builds - runner.stats.evictions
            == runner.cache_size())


def test_evict_counter_telemetry():
    with obs.enabled_scope(True) as reg:
        obs.reset()
        runner = BatchedRunner(capacity=1)
        _touch(runner, 3)
        _touch(runner, 4)  # evicts r=3
        _touch(runner, 3)  # evicts r=4, rebuilds r=3
        assert runner.stats.evictions == 2
        assert reg.counter("runner.cache.evict").value == 2
        assert reg.counter("runner.cache.hit", kind="block").value == 0
        _touch(runner, 3)
        assert reg.counter("runner.cache.hit", kind="block").value == 1


def test_lru_evicts_least_recently_used():
    runner = BatchedRunner(capacity=2)
    _touch(runner, 3)
    _touch(runner, 4)
    _touch(runner, 3)  # refresh r=3 -> r=4 is now LRU
    _touch(runner, 5)  # evicts r=4
    assert runner.is_cached("block", FRAC, 3, m=1)
    assert not runner.is_cached("block", FRAC, 4, m=1)
    assert runner.is_cached("block", FRAC, 5, m=1)


def test_is_cached_does_not_touch_lru_order():
    runner = BatchedRunner(capacity=2)
    _touch(runner, 3)
    _touch(runner, 4)
    assert runner.is_cached("block", FRAC, 3, m=1)  # a peek, not a use
    _touch(runner, 5)  # must evict r=3 (peek didn't refresh it)
    assert not runner.is_cached("block", FRAC, 3, m=1)
    assert runner.is_cached("block", FRAC, 4, m=1)


def test_invalidate_forces_rebuild():
    runner = BatchedRunner(capacity=4)
    e1 = _touch(runner, 4)
    assert runner.invalidate("block", FRAC, 4, m=1)
    assert not runner.is_cached("block", FRAC, 4, m=1)
    assert not runner.invalidate("block", FRAC, 4, m=1)  # already gone
    e2 = _touch(runner, 4)
    assert e2 is not e1
    assert runner.stats.builds == 2


def test_invalidated_engine_still_usable_by_old_holder():
    """A thread holding an engine across an invalidation (the abandoned
    hang-thread case) can still run it; results stay bit-exact with the
    rebuilt entry."""
    runner = BatchedRunner(capacity=4)
    old = _touch(runner, 4)
    state = old.init_random(0)
    runner.invalidate("block", FRAC, 4, m=1)
    new = _touch(runner, 4)
    a = np.asarray(old.run(state, 8))
    b = np.asarray(new.run(new.init_random(0), 8))
    np.testing.assert_array_equal(a, b)


def test_capacity_validation():
    with pytest.raises(ValueError):
        BatchedRunner(capacity=0)


def test_concurrent_run_results_bit_exact():
    """Batched runs from concurrent threads through the shared cache
    agree with a fresh single-engine reference."""
    runner = BatchedRunner(capacity=4)
    seeds = [0, 1, 2, 3]
    states = runner.init_batch("block", FRAC, 4, seeds, m=1,
                               workload=LIFE)

    def go(_):
        return np.asarray(
            runner.run("block", FRAC, 4, states, 6, m=1,
                       workload=LIFE))

    with ThreadPoolExecutor(max_workers=4) as ex:
        outs = list(ex.map(go, range(4)))
    for o in outs[1:]:
        np.testing.assert_array_equal(outs[0], o)
