"""Per-architecture smoke tests: a REDUCED config of each assigned arch
runs one forward and one train-grad step on CPU; output shapes + finiteness
asserted. The FULL configs are exercised via the dry-run only."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import model as model_lib

ARCHS = list(configs.ALL_ARCHS)


def _smoke_batch(cfg, key, b=2, s=16):
    ks = jax.random.split(key, 3)
    batch = {
        "tokens": jax.random.randint(ks[0], (b, s), 0, cfg.vocab),
        "labels": jax.random.randint(ks[1], (b, s), 0, cfg.vocab),
    }
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(
            ks[2], (b, cfg.enc_seq, cfg.d_model), jnp.float32)
    if cfg.n_patches:
        batch["prefix_embeds"] = jax.random.normal(
            ks[2], (b, cfg.n_patches, cfg.d_model), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_and_finite(arch):
    cfg = configs.get_smoke_config(arch)
    key = jax.random.PRNGKey(0)
    params = model_lib.init_params(key, cfg)
    batch = _smoke_batch(cfg, jax.random.PRNGKey(1))
    logits, _, aux = model_lib.forward(params, batch, cfg)
    b, s = batch["tokens"].shape
    expect_s = s + (cfg.n_patches or 0)
    assert logits.shape == (b, expect_s, cfg.vocab_padded), logits.shape
    assert bool(jnp.all(jnp.isfinite(logits))), f"{arch}: non-finite logits"
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", ARCHS)
def test_train_grad_step(arch):
    cfg = configs.get_smoke_config(arch)
    params = model_lib.init_params(jax.random.PRNGKey(0), cfg)
    batch = _smoke_batch(cfg, jax.random.PRNGKey(1))

    def loss_fn(p):
        loss, metrics = model_lib.train_loss(p, batch, cfg)
        return loss, metrics

    (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
    assert bool(jnp.isfinite(loss)), f"{arch}: loss={loss}"
    # every grad leaf finite and at least one nonzero
    leaves = jax.tree.leaves(grads)
    assert all(bool(jnp.all(jnp.isfinite(g))) for g in leaves)
    assert any(float(jnp.max(jnp.abs(g))) > 0 for g in leaves)
    # loss roughly ln(V) at init (uniform predictions)
    expected = np.log(cfg.vocab_padded)
    assert 0.3 * expected < float(metrics["ce"]) < 3.0 * expected


@pytest.mark.parametrize("arch", ["smollm-135m", "gemma2-2b", "mamba2-780m",
                                  "recurrentgemma-9b", "mixtral-8x22b",
                                  "whisper-small"])
def test_prefill_then_decode_matches_full_forward(arch):
    """Serving equivalence: prefill + K decode steps == full forward on the
    concatenated sequence (the KV-cache/state paths are consistent)."""
    cfg = configs.get_smoke_config(arch)
    params = model_lib.init_params(jax.random.PRNGKey(0), cfg)
    b, s_prompt, k_steps = 2, 8, 4
    total = s_prompt + k_steps
    tokens = jax.random.randint(jax.random.PRNGKey(2), (b, total), 0,
                                cfg.vocab)
    batch = {"tokens": tokens}
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(
            jax.random.PRNGKey(3), (b, cfg.enc_seq, cfg.d_model))

    # full forward over the whole sequence
    full_logits, _, _ = model_lib.forward(
        params, {**batch, "tokens": tokens}, cfg)

    # prefill on the prompt, then decode token by token
    cache = model_lib.init_cache(cfg, b, total)
    pre = {**batch, "tokens": tokens[:, :s_prompt]}
    last, cache, extras = model_lib.prefill(params, pre, cfg, cache)
    np.testing.assert_allclose(
        np.asarray(last), np.asarray(full_logits[:, s_prompt - 1]),
        rtol=2e-4, atol=2e-4)
    for i in range(k_steps - 1):
        pos = s_prompt + i
        last, cache = model_lib.decode_step(
            params, tokens[:, pos:pos + 1], pos, cfg, cache, extras=extras)
        np.testing.assert_allclose(
            np.asarray(last), np.asarray(full_logits[:, pos]),
            rtol=2e-4, atol=2e-4,
            err_msg=f"{arch}: decode step {i} diverged")


def test_param_counts_are_plausible():
    """Analytic param counts should land near the arch's nameplate size."""
    expectations = {
        "tinyllama-1.1b": (0.9e9, 1.4e9),
        "smollm-135m": (0.10e9, 0.18e9),
        "qwen1.5-110b": (0.9e11, 1.4e11),
        "mixtral-8x22b": (1.2e11, 1.6e11),
        "arctic-480b": (4.2e11, 5.2e11),
        "mamba2-780m": (0.6e9, 1.0e9),
        "gemma2-2b": (2.0e9, 3.3e9),
    }
    for arch, (lo, hi) in expectations.items():
        n = configs.get_config(arch).param_count()
        assert lo <= n <= hi, f"{arch}: {n:.3e} outside [{lo:.1e},{hi:.1e}]"


def test_greedy_generate_runs():
    cfg = configs.get_smoke_config("smollm-135m")
    params = model_lib.init_params(jax.random.PRNGKey(0), cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, cfg.vocab)
    out = model_lib.greedy_generate(params, {"tokens": tokens}, cfg,
                                    max_new=5, max_len=16)
    assert out.shape == (2, 5)
    assert bool(jnp.all(out >= 0))
