"""Sharded-vs-unsharded train-step equivalence (subprocess, 8 devices):
the production sharding rules must preserve the math."""
import os
import pathlib
import subprocess
import sys

import pytest


def _run(which: str):
    script = pathlib.Path(__file__).parent / "_sharded_equality_check.py"
    env = dict(os.environ)
    repo = pathlib.Path(__file__).resolve().parents[1]
    env["PYTHONPATH"] = str(repo / "src") + os.pathsep + env.get(
        "PYTHONPATH", "")
    out = subprocess.run([sys.executable, str(script), which], env=env,
                         capture_output=True, text=True, timeout=1800)
    assert out.returncode == 0, \
        f"stdout:\n{out.stdout}\nstderr:\n{out.stderr[-4000:]}"
    assert "SHARDED_EQ_OK" in out.stdout


def test_sharded_train_step_matches_unsharded_dense():
    _run("dense")


@pytest.mark.xfail(
    strict=False,
    reason="mixtral MoE shard-local dispatch diverges from the unsharded "
           "step on jax 0.4.x (worst relative param delta ~2); the dense "
           "smollm cases pass — needs a port of the expert all-to-all to "
           "the 0.4.x shard_map collectives")
def test_sharded_train_step_matches_unsharded_moe():
    _run("moe")
