"""Sharded-vs-unsharded train-step equivalence (subprocess, 8 devices):
the production sharding rules must preserve the math."""
import os
import pathlib
import subprocess
import sys


def _run(which: str):
    script = pathlib.Path(__file__).parent / "_sharded_equality_check.py"
    env = dict(os.environ)
    repo = pathlib.Path(__file__).resolve().parents[1]
    env["PYTHONPATH"] = str(repo / "src") + os.pathsep + env.get(
        "PYTHONPATH", "")
    out = subprocess.run([sys.executable, str(script), which], env=env,
                         capture_output=True, text=True, timeout=1800)
    assert out.returncode == 0, \
        f"stdout:\n{out.stdout}\nstderr:\n{out.stderr[-4000:]}"
    assert "SHARDED_EQ_OK" in out.stdout


def test_sharded_train_step_matches_unsharded_dense():
    _run("dense")


def test_sharded_train_step_matches_unsharded_moe():
    # Root cause of the old xfail: the shard-local dispatch group count
    # was an implicit function of the mesh, and MoE capacity is bounded
    # PER GROUP — so the g=1 unsharded reference dropped different tokens
    # than the g=4 sharded run (identical losses, wildly different expert
    # gradients). ``MoESpec.dispatch_groups`` now pins the grouping as
    # explicit model semantics; the check script pins it to the mesh's
    # batch degree on both sides, and the sharded step is a pure
    # re-layout of the same math.
    _run("moe")
