"""Sharded-vs-unsharded train-step equivalence (subprocess, 8 devices):
the production sharding rules must preserve the math."""
import os
import pathlib
import subprocess
import sys


def test_sharded_train_step_matches_unsharded():
    script = pathlib.Path(__file__).parent / "_sharded_equality_check.py"
    env = dict(os.environ)
    repo = pathlib.Path(__file__).resolve().parents[1]
    env["PYTHONPATH"] = str(repo / "src") + os.pathsep + env.get(
        "PYTHONPATH", "")
    out = subprocess.run([sys.executable, str(script)], env=env,
                         capture_output=True, text=True, timeout=1800)
    assert out.returncode == 0, \
        f"stdout:\n{out.stdout}\nstderr:\n{out.stderr[-4000:]}"
    assert "SHARDED_EQ_OK" in out.stdout
