"""Quickstart: Conway's game of life on a compact Sierpinski triangle —
the paper's case study, end to end in ~30 lines of API.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax.numpy as jnp

from repro.core import (BBEngine, BlockLayout, SIERPINSKI,
                        SqueezeBlockEngine, SqueezeCellEngine)

R = 7          # fractal level: n = 2^7 = 128, cells = 3^7 = 2187
STEPS = 50

# classic expanded bounding-box (the baseline the paper beats)
bb = BBEngine(SIERPINSKI, R)
s_bb = bb.init_random(seed=0)

# Squeeze: same simulation, compact memory (k^r cells instead of n^2)
cell = SqueezeCellEngine(SIERPINSKI, R)
s_cell = cell.init_random(seed=0)

# block-level Squeeze (rho=8), the paper's best-performing configuration
block = SqueezeBlockEngine(BlockLayout(SIERPINSKI, R, m=3))
s_blk = block.init_random(seed=0)

s_bb = bb.run(s_bb, STEPS)
s_cell = cell.run(s_cell, STEPS)
s_blk = block.run(s_blk, STEPS)

pop_bb = int(jnp.sum(s_bb))
pop_cell = int(jnp.sum(s_cell))
pop_blk = int(jnp.sum(s_blk))
print(f"after {STEPS} steps: population bb={pop_bb} "
      f"squeeze-cell={pop_cell} squeeze-block={pop_blk}")
assert pop_bb == pop_cell == pop_blk, "engines must agree"

mrf_cell = bb.memory_bytes() / cell.memory_bytes()
mrf_blk = bb.memory_bytes() / block.memory_bytes()
print(f"memory: bb={bb.memory_bytes()}B  compact={cell.memory_bytes()}B "
      f"(MRF {mrf_cell:.1f}x)  block={block.memory_bytes()}B "
      f"(MRF {mrf_blk:.1f}x)")
print("equal trajectories in compact space — P1 and P2 solved (paper §1.1)")
