"""End-to-end training driver: train a (reduced) smollm-135m on the
synthetic Markov corpus for a few hundred steps with the full production
loop — stateless data, AdamW, checkpointing, watchdog, preemption hook —
and verify the loss drops toward the corpus entropy.

    PYTHONPATH=src python examples/train_lm.py [--steps 300]
"""
import argparse
import math
import tempfile

import numpy as np

from repro import configs
from repro.data.pipeline import SyntheticMarkov
from repro.launch.train import train
from repro.optim import adamw
from repro.runtime.fault import PreemptionHandler


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--p-signal", type=float, default=0.9)
    args = ap.parse_args()

    cfg = configs.get_smoke_config("smollm-135m")
    data = SyntheticMarkov(vocab=cfg.vocab, seq_len=args.seq,
                           global_batch=args.batch,
                           p_signal=args.p_signal, seed=1)
    opt_cfg = adamw.AdamWConfig(lr=1e-3, warmup_steps=30,
                                total_steps=args.steps)

    # entropy of the channel: -p log p - (1-p) log((1-p)/V)
    p = args.p_signal
    v = cfg.vocab
    h = -p * math.log(p) - (1 - p) * math.log((1 - p) / v)
    print(f"corpus entropy ~ {h:.3f} nats; ln(V) = {math.log(v):.3f}")

    with tempfile.TemporaryDirectory() as ckpt:
        res = train(cfg, opt_cfg, data, steps=args.steps, ckpt_dir=ckpt,
                    ckpt_every=100, preemption=PreemptionHandler(),
                    log_every=25)
    first = float(np.mean(res.losses[:10]))
    last = float(np.mean(res.losses[-10:]))
    print(f"loss: {first:.3f} -> {last:.3f} "
          f"(corpus entropy {h:.3f})")
    assert last < first - 0.5, "expected a clear loss drop"
    print("OK: model learned the Markov structure")


if __name__ == "__main__":
    main()
