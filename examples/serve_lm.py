"""Batched serving example: prefill + KV-cached greedy decode with the
BatchServer (ring buffers on windowed layers), on a reduced gemma2-2b —
exercising sliding-window + softcap + tied embeddings in the serve path.

    PYTHONPATH=src python examples/serve_lm.py
"""
import jax
import numpy as np

from repro import configs
from repro.launch.serve import BatchServer, Request
from repro.models import model as model_lib


def main():
    cfg = configs.get_smoke_config("gemma2-2b")
    params = model_lib.init_params(jax.random.PRNGKey(0), cfg)
    server = BatchServer(cfg, params, max_len=256)

    rng = np.random.default_rng(0)
    requests = [
        Request(prompt=rng.integers(0, cfg.vocab, size=n).astype(np.int32),
                max_new=12)
        for n in (24, 17, 31, 8)   # ragged prompts, left-padded batch
    ]
    server.serve(requests)
    for i, r in enumerate(requests):
        assert r.out is not None and len(r.out) == 12
        print(f"request {i} (prompt {len(r.prompt)} toks) -> {r.out}")
    print("OK: batched prefill+decode served all requests")


if __name__ == "__main__":
    main()
