"""Distributed Squeeze end to end on 8 (placeholder CPU) devices: one
compact fractal sharded over the mesh's block axis, k-fused strip halo
exchange (neighbor-only ppermute by default, all-gather fallback),
single-device parity, and the k-fusion knob's effect on the collective
count and exchanged bytes.

    PYTHONPATH=src python examples/distributed.py

The 8 host-platform devices are forced before jax is imported — on a
real TPU slice, drop the flag and the same engine shards over the real
mesh unchanged.
"""
import os

os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=8")

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.core import SIERPINSKI  # noqa: E402
from repro.core.compact import BlockLayout  # noqa: E402
from repro.core.distributed import make_distributed_engine  # noqa: E402
from repro.core.stencil import SqueezeBlockEngine  # noqa: E402
from repro.tuning import EngineSpec  # noqa: E402
from repro.workloads import GRAY_SCOTT, LIFE, BatchedRunner  # noqa: E402

R, M, STEPS = 7, 2, 12
print(f"devices: {jax.device_count()} x {jax.devices()[0].platform}")

layout = BlockLayout(SIERPINSKI, R, M)
print(f"sierpinski r={R}, m={M}: {layout.n_blocks} blocks of "
      f"{layout.rho}x{layout.rho} cells "
      f"({layout.memory_bytes()} compact bytes vs "
      f"{SIERPINSKI.side(R) ** 2} dense)")

# ---- single-device oracle ------------------------------------------------
ref_engine = SqueezeBlockEngine(layout, LIFE, fusion_k=1)
ref = ref_engine.init_random(42)
for _ in range(STEPS):
    ref = ref_engine.step(ref)

# ---- distributed: the k-fusion knob --------------------------------------
# k=1 is the every-step-exchange baseline (one halo exchange per step);
# fused k>=2 exchanges depth-k strips ONCE per k steps — ceil(STEPS/k)
# exchanges for the whole run, bit-exact for CA workloads.  exchange
# defaults to 'auto': neighbor-only ppermute whenever the strip
# decomposition is valid (it is here), all-gather otherwise.
for k in (1, 2, 4):
    dist = make_distributed_engine(layout, workload=LIFE, compute="jnp",
                                   fusion_k=k)
    out = dist.run(dist.init_random(42), STEPS)
    exact = bool((np.asarray(dist.to_dense(out)) == np.asarray(ref)).all())
    st = dist.exchange_stats()
    noun = ("permute pairs" if dist.exchange_mode == "p2p"
            else "all-gathers")
    print(f"k={k}: {st.collectives:2d} {noun} for {STEPS} steps "
          f"({st.collectives_per_step:.2f}/step, "
          f"{st.bytes_per_step / 1024:.1f} KiB exchanged/step), "
          f"shard-local state {dist.memory_bytes() // dist.n_shards} B, "
          f"bit-exact vs single device: {exact}")

# ---- shard-local kernel computes + multi-channel PDE ---------------------
# 'mxu' runs the v5 stencil-as-matmul macro-tile kernel on each shard's
# local blocks (Pallas interpreter off-TPU, Mosaic-compiled on TPU)
dist = make_distributed_engine(layout, workload=GRAY_SCOTT, compute="mxu",
                               fusion_k=2)
out = dist.run(dist.init_random(7), STEPS)
gs_ref_engine = SqueezeBlockEngine(layout, GRAY_SCOTT, fusion_k=1)
gs_ref = gs_ref_engine.init_random(7)
for _ in range(STEPS):
    gs_ref = gs_ref_engine.step(gs_ref)
close = bool(np.allclose(np.asarray(dist.to_dense(out)),
                         np.asarray(gs_ref), rtol=1e-5, atol=1e-5))
print(f"gray-scott via shard-local MXU kernel, k=2: allclose vs single "
      f"device: {close}")

# ---- the serving runtime picks the placement -----------------------------
# many small fractals -> batch-axis sharding (whole sims per device);
# one big fractal -> block-axis sharding through the dist-* kinds.
# Spec-first (DESIGN.md Section 11): the EngineSpec carries the kind,
# fusion depth, exchange mode and mesh bucket in one identity.
runner = BatchedRunner()
mesh = jax.sharding.Mesh(np.array(jax.devices()), ("data",))
big = EngineSpec.from_args("dist-block", SIERPINSKI, R, M, LIFE,
                           fusion_k=2, mesh=mesh)
states = runner.init_batch(big, range(4), mesh=mesh)
states = runner.run(big, states, STEPS, mesh=mesh)
print(f"runner: 4 sims x {STEPS} steps, block-axis sharded, state "
      f"{tuple(states.shape)} — one batched strip exchange per fused "
      f"launch")
small_spec = EngineSpec.from_args("block", SIERPINSKI, 5, M, LIFE)
small = runner.init_batch(small_spec, range(8), mesh=mesh)
small = runner.run(small_spec, small, STEPS)
print(f"runner: 8 small sims batch-axis sharded over the same mesh, "
      f"state {tuple(small.shape)}, population "
      f"{int(jnp.sum(small))}")
