"""The serving layer end to end: heterogeneous requests continuously
batched onto shared compiled engines, then the same workload surviving
an injected crash and a corrupted checkpoint — recovering bit-exact.

    PYTHONPATH=src python examples/serve_fractals.py

See DESIGN.md Section 8 for the architecture, the chaos matrix and the
recovery state machine.
"""
import tempfile

import numpy as np

from repro import obs
from repro.core import SIERPINSKI, VICSEK
from repro.runtime.fault import Fault, FaultInjector
from repro.serving import FractalService, ServiceConfig, SimRequest
from repro.workloads import HEAT, LIFE

obs.enable()

# ---- 1. a mixed batch: three buckets (engine-compatibility classes),
# heterogeneous step counts and snapshot cadences within each
reqs = [
    SimRequest(frac=SIERPINSKI, r=5, m=2, steps=24, seed=0,
               snapshot_every=8, rid="life-a"),
    SimRequest(frac=SIERPINSKI, r=5, m=2, steps=40, seed=1,
               rid="life-b"),
    SimRequest(frac=SIERPINSKI, r=5, m=2, steps=16, seed=2,
               rid="life-c"),
    SimRequest(frac=SIERPINSKI, r=5, m=2, steps=24, seed=0,
               workload=HEAT, rid="heat-a"),
    SimRequest(frac=VICSEK, r=4, m=1, steps=24, seed=0,
               rid="vicsek-a"),
]
svc = FractalService(ServiceConfig(max_batch=8))
results = svc.serve(reqs)
for r in results:
    print(f"  {r.rid:10s} {r.status:4s} steps={r.steps_done:3d} "
          f"snapshots={len(r.snapshots)} latency={r.latency_s:.3f}s")

# ---- 2. chaos: the same requests with a crash injected at segment 1
# and the newest checkpoint corrupted at segment 2 — the supervisor
# retries with backoff, restores through the crc32 fallback walk, and
# the final states match the undisturbed run above bit for bit
with tempfile.TemporaryDirectory() as ckpts:
    inj = FaultInjector([Fault(kind="exception", at_segment=1),
                         Fault(kind="corrupt", at_segment=2),
                         Fault(kind="exception", at_segment=3)])
    chaos = FractalService(
        ServiceConfig(max_batch=8, ckpt_dir=ckpts,
                      backoff_base_s=0.02), injector=inj)
    survived = chaos.serve(reqs)

for clean, dirty in zip(results, survived):
    same = (clean.state.dtype.kind in "fc"
            and np.allclose(clean.state, dirty.state)
            or np.array_equal(clean.state, dirty.state))
    print(f"  {dirty.rid:10s} {dirty.status:4s} "
          f"retries={dirty.retries} recoveries={dirty.recoveries} "
          f"bit-exact={bool(same)}")
print("\ninjected faults:", [(seg, kind) for seg, kind, _ in inj.log])

# ---- 3. the service's telemetry surface
print()
print("\n".join(line for line in obs.report().splitlines()
                if "serve." in line or "chaos." in line))
