"""Beyond the paper's case study: four workloads on one compact fractal,
and a batched runtime serving 8 concurrent simulations per workload from
a single compiled engine.

Spec-first (DESIGN.md Section 11): an ``EngineSpec`` names the
configuration once and the runner/engine/tuning-table all key on it.
With ``fusion_k=None`` the shipped tuning table resolves the fusion
depth (falling back to the static heuristic off-table).

    PYTHONPATH=src python examples/workloads.py
"""
import jax.numpy as jnp

from repro.core import SIERPINSKI
from repro.tuning import EngineSpec
from repro.workloads import (GRAY_SCOTT, HEAT, HIGHLIFE, LIFE, BatchedRunner)

R, M, STEPS, BATCH = 6, 2, 20, 8

runner = BatchedRunner()
for wl in (LIFE, HIGHLIFE, HEAT, GRAY_SCOTT):
    spec = EngineSpec.from_args("block", SIERPINSKI, R, M, wl)
    states = runner.init_batch(spec, range(BATCH))
    states = runner.run(spec, states, STEPS)
    if wl.dtype == jnp.uint8:
        stat = f"mean population {float(jnp.sum(states)) / BATCH:.0f}"
    else:
        stat = f"mean field {float(jnp.mean(states)):.4f}"
    k = runner.engine_for(spec).effective_fusion_k
    print(f"{wl.name:>10}: {BATCH} sims x {STEPS} steps (fusion k={k}), "
          f"state {tuple(states.shape)} {jnp.dtype(wl.dtype).name}, {stat}")

s = runner.stats
print(f"compiled engines built: {s.builds} (one per workload), "
      f"traces: {s.traces} — each batch of {BATCH} sims shares one")

# the v5 MXU path: same serving surface, but the whole batch advances
# through ONE kernel dispatched over a (B, n_macro_tiles) grid — the
# stencil runs as banded matmuls on lane-packed macro-tiles (DESIGN 2.2).
# Deliberately the LEGACY argument form: it still works (one
# DeprecationWarning), lands in the same cache slot as the spec form,
# and keeps the shim covered by an executable example.
states = runner.init_batch("pallas-mxu", SIERPINSKI, R, seeds=range(BATCH),
                           m=M, workload=HEAT)
states = runner.run("pallas-mxu", SIERPINSKI, R, states, steps=STEPS, m=M,
                    workload=HEAT)
print(f"pallas-mxu: {BATCH} sims x {STEPS} steps in batch-grid dispatches, "
      f"mean field {float(jnp.mean(states)):.4f}")
