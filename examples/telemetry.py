"""The telemetry layer end to end: run a batch on the distributed-fused
engine with collection enabled, then read the registry back four ways —
pretty table, Prometheus text, JSONL, and a Chrome trace of the span
tree (load it at chrome://tracing or ui.perfetto.dev).

    PYTHONPATH=src python examples/telemetry.py

Collection is opt-in (SQUEEZE_TELEMETRY=1, or obs.enable() as below);
disabled, every hook is a bool check — the CI `telemetry` gate holds
the instrumented-but-disabled hot path within 2% of uninstrumented.
"""
import tempfile

from repro import obs
from repro.core import SIERPINSKI
from repro.workloads import LIFE, BatchedRunner

R, M, STEPS, BATCH = 5, 2, 12, 4

obs.enable()

runner = BatchedRunner()
with obs.span("example", r=R, batch=BATCH):
    states = runner.init_batch("dist-fused", SIERPINSKI, R,
                               seeds=range(BATCH), m=M, workload=LIFE)
    states = runner.run("dist-fused", SIERPINSKI, R, states,
                        steps=STEPS, m=M, workload=LIFE)

# 1. the human-readable table: cache hits, fused launches, collectives,
#    memory-bytes gauges, per-run latency histograms
print(obs.report())

# 2. Prometheus scrape text (squeeze_* families)
prom = obs.to_prometheus()
print("\n".join(line for line in prom.splitlines()
                if line.startswith("# TYPE"))[:400])

# 3. JSONL event log (round-trips via obs.load_jsonl)
jsonl = obs.to_jsonl()
print(f"\njsonl: {len(jsonl.splitlines())} lines, "
      f"{len(jsonl)} bytes")

# 4. the span tree as a Chrome trace
with tempfile.NamedTemporaryFile(suffix=".json", delete=False) as f:
    path = obs.write_chrome_trace(f.name)
root = obs.spans()[-1]
print(f"chrome trace: {path} — root span '{root.name}' "
      f"{root.dur_us / 1e3:.1f} ms, {len(root.children)} children")
