"""The paper's capability claim (Section 4.3): Squeeze processes fractal
levels whose bounding-box embedding could never fit. We run a level the
BB engine would need ~16 GiB for, in ~a hundred MiB of compact state, and
also demo the multi-device engine if more than one device is visible.

    PYTHONPATH=src python examples/fractal_large.py [--r 17]
    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python examples/fractal_large.py --distributed
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.core import BlockLayout, SIERPINSKI, SqueezeBlockEngine
from repro.core.distributed import make_distributed_engine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--r", type=int, default=14,
                    help="fractal level (n = 2^r); BB needs 4^r bytes")
    ap.add_argument("--m", type=int, default=4, help="block level (rho=2^m)")
    ap.add_argument("--steps", type=int, default=5)
    ap.add_argument("--distributed", action="store_true")
    args = ap.parse_args()

    frac = SIERPINSKI
    layout = BlockLayout(frac, args.r, args.m)
    bb_bytes = frac.side(args.r) ** 2
    sq_bytes = layout.memory_bytes()
    print(f"level r={args.r}: n={frac.side(args.r)}, "
          f"BB would need {bb_bytes / 2**30:.2f} GiB; "
          f"Squeeze uses {sq_bytes / 2**20:.1f} MiB "
          f"(MRF {bb_bytes / sq_bytes:.0f}x)")

    if args.distributed and jax.device_count() > 1:
        eng = make_distributed_engine(layout)
        print(f"distributed over {jax.device_count()} devices "
              f"(strip halo exchange)")
    else:
        eng = SqueezeBlockEngine(layout)

    state = eng.init_random(seed=0)
    t0 = time.time()
    state = eng.run(state, args.steps)
    jax.block_until_ready(state)
    dt = time.time() - t0
    cells = frac.volume(args.r)
    print(f"{args.steps} steps over {cells:,} fractal cells in {dt:.2f}s "
          f"({args.steps * cells / dt / 1e6:.1f} Mcell-updates/s)")
    print(f"live cells: {int(jnp.sum(state)):,}")


if __name__ == "__main__":
    main()
